//! Warping envelopes — the derived series at the heart of `LB_KEOGH` and
//! every bound built on it.
//!
//! For a series `S` and window `w`, the upper and lower envelopes are
//!
//! ```text
//! U_i = max_{max(0,i-w) ≤ j ≤ min(ℓ-1,i+w)} S_j
//! L_i = min_{max(0,i-w) ≤ j ≤ min(ℓ-1,i+w)} S_j
//! ```
//!
//! Computed in `O(ℓ)` (independent of `w`) with Lemire's monotonic-deque
//! streaming min/max [Lemire 2009], which is what gives the whole bound
//! family its "constant complexity with respect to window size" property.
//!
//! `LB_WEBB` additionally uses *envelopes of envelopes*
//! (`𝕌^{𝕃^B}`, `𝕃^{𝕌^B}`) — just the same routine applied twice.

/// Compute lower and upper envelopes of `s` for window `w` into the
/// provided buffers (resized as needed). `O(ℓ)` via monotonic deques.
///
/// The deques are flat index rings in a thread-local scratch allocation —
/// `VecDeque` showed up at ~17% of NN-search profiles from per-call
/// allocation and wrap-around arithmetic (§Perf O2 in EXPERIMENTS.md).
///
/// This admit/expire pass is deliberately **not** vectorised: its control
/// flow is data-dependent (each admission pops a variable number of deque
/// entries), so it stays scalar while its consumers — the min/clamp and
/// merge loops over the envelopes it produces — run on the
/// [`crate::simd`] vtable. That split keeps envelope *values* identical
/// across ISAs by construction.
pub fn envelopes_into(s: &[f64], w: usize, lo: &mut Vec<f64>, up: &mut Vec<f64>) {
    let n = s.len();
    assert!(n > 0, "envelope of empty series");
    lo.clear();
    up.clear();
    lo.resize(n, 0.0);
    up.resize(n, 0.0);
    if w == 0 {
        lo.copy_from_slice(s);
        up.copy_from_slice(s);
        return;
    }

    thread_local! {
        static IDX: std::cell::RefCell<Vec<u32>> = const { std::cell::RefCell::new(Vec::new()) };
    }
    IDX.with(|cell| {
        let mut buf = cell.borrow_mut();
        buf.clear();
        buf.resize(2 * n, 0);
        let (max_q, min_q) = buf.split_at_mut(n);
        // Plain head/tail cursors into the two index arrays. A deque
        // index enters at the tail monotone in value and expires at the
        // head when it leaves the window — no wrap-around ever occurs
        // because indices are strictly increasing and at most n live.
        let (mut max_h, mut max_t) = (0usize, 0usize); // [h, t) live
        let (mut min_h, mut min_t) = (0usize, 0usize);

        let mut admit = |j: usize,
                         max_q: &mut [u32],
                         min_q: &mut [u32],
                         max_h: &usize,
                         max_t: &mut usize,
                         min_h: &usize,
                         min_t: &mut usize| {
            let v = s[j];
            while *max_t > *max_h && s[max_q[*max_t - 1] as usize] <= v {
                *max_t -= 1;
            }
            max_q[*max_t] = j as u32;
            *max_t += 1;
            while *min_t > *min_h && s[min_q[*min_t - 1] as usize] >= v {
                *min_t -= 1;
            }
            min_q[*min_t] = j as u32;
            *min_t += 1;
        };

        // Prime with the first window [0, min(w, n-1)].
        for j in 0..=w.min(n - 1) {
            admit(j, max_q, min_q, &max_h, &mut max_t, &min_h, &mut min_t);
        }
        up[0] = s[max_q[max_h] as usize];
        lo[0] = s[min_q[min_h] as usize];

        for i in 1..n {
            // Admit the new right edge j = i + w.
            let j = i + w;
            if j < n {
                admit(j, max_q, min_q, &max_h, &mut max_t, &min_h, &mut min_t);
            }
            // Expire the old left edge j = i - w - 1.
            if i > w {
                let expired = (i - w - 1) as u32;
                if max_q[max_h] == expired {
                    max_h += 1;
                }
                if min_q[min_h] == expired {
                    min_h += 1;
                }
            }
            up[i] = s[max_q[max_h] as usize];
            lo[i] = s[min_q[min_h] as usize];
        }
    });
}

/// Convenience allocating wrapper around [`envelopes_into`]:
/// returns `(lower, upper)`.
pub fn envelopes(s: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    let mut lo = Vec::new();
    let mut up = Vec::new();
    envelopes_into(s, w, &mut lo, &mut up);
    (lo, up)
}

/// Merge one member envelope into a cluster accumulator, elementwise:
/// `acc_lo[i] = min(acc_lo[i], lo[i])`, `acc_up[i] = max(acc_up[i],
/// up[i])`.
///
/// Folding every member of a cluster this way (accumulator seeded with
/// `+INFINITY` / `-INFINITY`) yields the cluster's **merged envelope**,
/// which *contains* each member's envelope: `merged_lo ≤ member_lo` and
/// `merged_up ≥ member_up` pointwise. `LB_KEOGH` against a containing
/// envelope can only shrink (every query sample's exceedance distance
/// shrinks or vanishes), so the merged-envelope bound lower-bounds every
/// member's own `LB_KEOGH` — and hence every member's DTW distance. That
/// containment argument is what makes cluster-level pruning exact; see
/// ARCHITECTURE.md "Sublinear pruning".
///
/// Runs on the runtime-selected SIMD vtable ([`crate::simd::kernels`]).
/// The elementwise min/max use hardware select semantics (`minpd` /
/// `maxpd`: the incoming member value wins exact ties, e.g. ±0.0) —
/// bit-identical at every ISA, and value-identical to the pre-SIMD
/// keep-first-on-tie fold.
pub fn merge_envelopes_into(acc_lo: &mut [f64], acc_up: &mut [f64], lo: &[f64], up: &[f64]) {
    debug_assert_eq!(acc_lo.len(), lo.len(), "one shared length");
    debug_assert_eq!(acc_up.len(), up.len(), "one shared length");
    let k = crate::simd::kernels();
    (k.min_merge)(acc_lo, lo);
    (k.max_merge)(acc_up, up);
}

/// Incremental (streaming) envelope maintainer — the online counterpart
/// of [`envelopes_into`], for unbounded sample streams.
///
/// Feed samples one at a time with [`StreamingEnvelope::push`]; envelope
/// values come back **in position order**, each as soon as its window
/// `[i-w, i+w]` has fully arrived (i.e. with a fixed latency of `w`
/// samples). After the last sample, [`StreamingEnvelope::flush_next`]
/// drains the `min(w, n)` tail positions, whose windows are clipped at
/// the stream end exactly as the batch routine clips them at the series
/// end. The sequence of emitted `(lo, up)` pairs is therefore **bit-equal
/// to the batch envelopes** of the full sample sequence — the property
/// test `streaming_matches_batch_on_random_series` pins this down, so
/// sample-at-a-time consumers (monitoring pipelines feeding
/// `stream::SubsequenceSearcher`-style workloads) can maintain envelopes
/// online and still agree exactly with batch-prepared data.
///
/// Complexity: `O(1)` amortized per sample (each sample enters and leaves
/// each monotonic deque at most once), `O(w)` memory independent of the
/// stream length.
#[derive(Debug, Clone)]
pub struct StreamingEnvelope {
    w: usize,
    /// Samples pushed so far (the next sample gets this index).
    pushed: u64,
    /// Envelope positions emitted so far (the next emit is for this index).
    emitted: u64,
    /// `(index, value)` with values strictly decreasing front→back.
    max_q: std::collections::VecDeque<(u64, f64)>,
    /// `(index, value)` with values strictly increasing front→back.
    min_q: std::collections::VecDeque<(u64, f64)>,
}

impl StreamingEnvelope {
    /// A maintainer for window `w` (the same `w` as [`envelopes_into`]).
    pub fn new(w: usize) -> StreamingEnvelope {
        StreamingEnvelope {
            w,
            pushed: 0,
            emitted: 0,
            max_q: std::collections::VecDeque::with_capacity(w + 1),
            min_q: std::collections::VecDeque::with_capacity(w + 1),
        }
    }

    /// The window this maintainer computes envelopes for.
    #[inline]
    pub fn window(&self) -> usize {
        self.w
    }

    /// Samples pushed so far.
    #[inline]
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Envelope positions emitted so far (always `≤ pushed`).
    #[inline]
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Reset to an empty stream, optionally with a new window. Keeps the
    /// deque allocations (for per-window reuse on hot paths).
    pub fn reset(&mut self, w: usize) {
        self.w = w;
        self.pushed = 0;
        self.emitted = 0;
        self.max_q.clear();
        self.min_q.clear();
    }

    /// Emit the envelope for position `emitted`, expiring deque entries
    /// that fell off the left edge of its window.
    fn emit(&mut self) -> (f64, f64) {
        let i = self.emitted;
        let left = i.saturating_sub(self.w as u64);
        while self.max_q.front().is_some_and(|&(j, _)| j < left) {
            self.max_q.pop_front();
        }
        while self.min_q.front().is_some_and(|&(j, _)| j < left) {
            self.min_q.pop_front();
        }
        self.emitted += 1;
        let lo = self.min_q.front().expect("window non-empty").1;
        let up = self.max_q.front().expect("window non-empty").1;
        (lo, up)
    }

    /// Push the next sample. Returns `Some((lo, up))` for the oldest
    /// not-yet-emitted position once its full window `[i-w, i+w]` has
    /// arrived — i.e. the envelope of position `pushed - 1 - w`, delayed
    /// by exactly `w` samples (no delay when `w == 0`).
    pub fn push(&mut self, v: f64) -> Option<(f64, f64)> {
        let j = self.pushed;
        self.pushed += 1;
        while self.max_q.back().is_some_and(|&(_, x)| x <= v) {
            self.max_q.pop_back();
        }
        self.max_q.push_back((j, v));
        while self.min_q.back().is_some_and(|&(_, x)| x >= v) {
            self.min_q.pop_back();
        }
        self.min_q.push_back((j, v));
        if j >= self.emitted + self.w as u64 {
            Some(self.emit())
        } else {
            None
        }
    }

    /// After the last sample: emit the next pending tail position, whose
    /// window is clipped at the stream end (exactly the batch routine's
    /// end-of-series behaviour). Returns `None` when every pushed
    /// position has been emitted.
    pub fn flush_next(&mut self) -> Option<(f64, f64)> {
        if self.emitted < self.pushed {
            Some(self.emit())
        } else {
            None
        }
    }

    /// Convenience: run a whole series through the maintainer, appending
    /// every emitted pair to `lo`/`up` (cleared first). Produces exactly
    /// [`envelopes_into`]'s output.
    pub fn compute_into(&mut self, s: &[f64], lo: &mut Vec<f64>, up: &mut Vec<f64>) {
        assert!(!s.is_empty(), "envelope of empty series");
        let w = self.w;
        self.reset(w);
        lo.clear();
        up.clear();
        lo.reserve(s.len());
        up.reserve(s.len());
        for &v in s {
            if let Some((l, u)) = self.push(v) {
                lo.push(l);
                up.push(u);
            }
        }
        while let Some((l, u)) = self.flush_next() {
            lo.push(l);
            up.push(u);
        }
    }
}

/// Naive `O(ℓ·w)` reference used by tests.
pub fn envelopes_naive(s: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    let n = s.len();
    let mut lo = vec![0.0; n];
    let mut up = vec![0.0; n];
    for i in 0..n {
        let a = i.saturating_sub(w);
        let b = (i + w).min(n - 1);
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for j in a..=b {
            mn = mn.min(s[j]);
            mx = mx.max(s[j]);
        }
        lo[i] = mn;
        up[i] = mx;
    }
    (lo, up)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn matches_naive_on_random_series() {
        let mut rng = Rng::seeded(42);
        for &n in &[1usize, 2, 3, 5, 17, 64, 257] {
            for &w in &[0usize, 1, 2, 3, 7, 50, 1000] {
                let s: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
                let (lo_f, up_f) = envelopes(&s, w);
                let (lo_n, up_n) = envelopes_naive(&s, w);
                assert_eq!(lo_f, lo_n, "lo n={n} w={w}");
                assert_eq!(up_f, up_n, "up n={n} w={w}");
            }
        }
    }

    #[test]
    fn envelope_sandwiches_series() {
        let mut rng = Rng::seeded(7);
        let s: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        for w in [0, 1, 5, 20] {
            let (lo, up) = envelopes(&s, w);
            for i in 0..s.len() {
                assert!(lo[i] <= s[i] && s[i] <= up[i]);
            }
        }
    }

    #[test]
    fn window_zero_is_identity() {
        let s = [3.0, -1.0, 4.0];
        let (lo, up) = envelopes(&s, 0);
        assert_eq!(lo, s.to_vec());
        assert_eq!(up, s.to_vec());
    }

    #[test]
    fn window_full_is_global_extrema() {
        let s = [3.0, -1.0, 4.0, 0.5];
        let (lo, up) = envelopes(&s, 10);
        assert!(lo.iter().all(|&v| v == -1.0));
        assert!(up.iter().all(|&v| v == 4.0));
    }

    #[test]
    fn envelopes_widen_with_window() {
        let mut rng = Rng::seeded(13);
        let s: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let mut prev = envelopes(&s, 0);
        for w in 1..12 {
            let cur = envelopes(&s, w);
            for i in 0..s.len() {
                assert!(cur.0[i] <= prev.0[i] && cur.1[i] >= prev.1[i]);
            }
            prev = cur;
        }
    }

    #[test]
    fn single_element_series() {
        for w in [0usize, 1, 5, 100] {
            let (lo, up) = envelopes(&[2.5], w);
            assert_eq!(lo, vec![2.5], "w={w}");
            assert_eq!(up, vec![2.5], "w={w}");
            let mut env = StreamingEnvelope::new(w);
            let (mut slo, mut sup) = (Vec::new(), Vec::new());
            env.compute_into(&[2.5], &mut slo, &mut sup);
            assert_eq!(slo, lo, "w={w}");
            assert_eq!(sup, up, "w={w}");
        }
    }

    #[test]
    fn constant_series_envelopes_are_the_constant() {
        let s = [4.25; 17];
        for w in [0usize, 1, 3, 16, 17, 40] {
            let (lo, up) = envelopes(&s, w);
            assert!(lo.iter().all(|&v| v == 4.25), "w={w}");
            assert!(up.iter().all(|&v| v == 4.25), "w={w}");
            let mut env = StreamingEnvelope::new(w);
            let (mut slo, mut sup) = (Vec::new(), Vec::new());
            env.compute_into(&s, &mut slo, &mut sup);
            assert_eq!(slo, lo, "w={w}");
            assert_eq!(sup, up, "w={w}");
        }
    }

    #[test]
    fn window_at_and_beyond_length_is_global_extrema() {
        let s = [3.0, -1.0, 4.0, 0.5, 2.0];
        // w = len-1 is already unconstrained; larger w must not change it.
        for w in [s.len() - 1, s.len(), s.len() + 1, 10 * s.len()] {
            let (lo, up) = envelopes(&s, w);
            assert!(lo.iter().all(|&v| v == -1.0), "w={w}");
            assert!(up.iter().all(|&v| v == 4.0), "w={w}");
        }
    }

    /// The tentpole invariant: the streaming maintainer emits exactly the
    /// batch envelopes — same values, same order, bit-equal — across
    /// random series, window grids and both the push and flush paths.
    #[test]
    fn streaming_matches_batch_on_random_series() {
        let mut rng = Rng::seeded(20_26);
        for &n in &[1usize, 2, 3, 5, 16, 63, 257] {
            for &w in &[0usize, 1, 2, 3, 7, 31, 300] {
                let s: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0).collect();
                let (lo_b, up_b) = envelopes(&s, w);

                // Manual push/flush loop (checks emission latency too).
                let mut env = StreamingEnvelope::new(w);
                let mut lo_s = Vec::new();
                let mut up_s = Vec::new();
                for (j, &v) in s.iter().enumerate() {
                    match env.push(v) {
                        Some((l, u)) => {
                            assert!(j >= w, "emitted before the window arrived");
                            lo_s.push(l);
                            up_s.push(u);
                        }
                        None => assert!(j < w, "push {j} should have emitted (w={w})"),
                    }
                }
                while let Some((l, u)) = env.flush_next() {
                    lo_s.push(l);
                    up_s.push(u);
                }
                assert!(env.flush_next().is_none(), "flush drains exactly once");
                assert_eq!(lo_s, lo_b, "lo n={n} w={w}");
                assert_eq!(up_s, up_b, "up n={n} w={w}");

                // Reuse the same maintainer via compute_into (reset path).
                let (mut lo_c, mut up_c) = (vec![0.0; 3], vec![0.0; 3]);
                env.compute_into(&s, &mut lo_c, &mut up_c);
                assert_eq!(lo_c, lo_b, "compute_into lo n={n} w={w}");
                assert_eq!(up_c, up_b, "compute_into up n={n} w={w}");
            }
        }
    }

    #[test]
    fn streaming_envelope_memory_stays_bounded() {
        // The deques never hold more than one window's worth of
        // candidates, regardless of how long the stream runs.
        let mut rng = Rng::seeded(5150);
        let w = 9;
        let mut env = StreamingEnvelope::new(w);
        for _ in 0..10_000 {
            env.push(rng.normal());
            assert!(env.max_q.len() <= 2 * w + 1);
            assert!(env.min_q.len() <= 2 * w + 1);
        }
        assert_eq!(env.emitted(), 10_000 - w as u64);
    }

    #[test]
    fn merged_envelope_contains_members_and_weakens_lb_keogh() {
        use crate::bounds::keogh::lb_keogh_flat;
        use crate::delta::Squared;
        let mut rng = Rng::seeded(2102);
        let l = 64;
        let w = 4;
        let members: Vec<Vec<f64>> =
            (0..6).map(|_| (0..l).map(|_| rng.normal()).collect()).collect();
        let envs: Vec<(Vec<f64>, Vec<f64>)> =
            members.iter().map(|s| envelopes(s, w)).collect();
        let mut acc_lo = vec![f64::INFINITY; l];
        let mut acc_up = vec![f64::NEG_INFINITY; l];
        for (lo, up) in &envs {
            merge_envelopes_into(&mut acc_lo, &mut acc_up, lo, up);
        }
        // Containment: the merged envelope sandwiches every member's.
        for (mi, (lo, up)) in envs.iter().enumerate() {
            for i in 0..l {
                assert!(acc_lo[i] <= lo[i], "member {mi} lo at {i}");
                assert!(acc_up[i] >= up[i], "member {mi} up at {i}");
            }
        }
        // The exactness lemma: LB_KEOGH(query, merged) never exceeds
        // LB_KEOGH(query, member) for any member.
        for _ in 0..8 {
            let q: Vec<f64> = (0..l).map(|_| rng.normal()).collect();
            let merged = lb_keogh_flat::<Squared>(&q, &acc_lo, &acc_up, f64::INFINITY);
            for (mi, (lo, up)) in envs.iter().enumerate() {
                let member = lb_keogh_flat::<Squared>(&q, lo, up, f64::INFINITY);
                assert!(merged <= member + 1e-12, "member {mi}: {merged} > {member}");
            }
        }
    }

    #[test]
    fn envelope_of_envelope_nests() {
        // 𝕃^{𝕌^B} lies between 𝕃^B-ish bounds: L_i <= LUB_i <= U_i etc.
        let mut rng = Rng::seeded(99);
        let s: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        for w in [1usize, 3, 9] {
            let (lo, up) = envelopes(&s, w);
            let (lub, _) = envelopes(&up, w);
            let (_, ulb) = envelopes(&lo, w);
            for i in 0..s.len() {
                assert!(lub[i] <= up[i] + 1e-15);
                assert!(ulb[i] >= lo[i] - 1e-15);
                // The key LB_Webb fact: within j's window every U_i >= LUB_j.
                let a = i.saturating_sub(w);
                let b = (i + w).min(s.len() - 1);
                for j in a..=b {
                    assert!(lub[i] <= up[j] + 1e-15);
                    assert!(ulb[i] >= lo[j] - 1e-15);
                }
            }
        }
    }
}
