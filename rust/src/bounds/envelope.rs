//! Warping envelopes — the derived series at the heart of `LB_KEOGH` and
//! every bound built on it.
//!
//! For a series `S` and window `w`, the upper and lower envelopes are
//!
//! ```text
//! U_i = max_{max(0,i-w) ≤ j ≤ min(ℓ-1,i+w)} S_j
//! L_i = min_{max(0,i-w) ≤ j ≤ min(ℓ-1,i+w)} S_j
//! ```
//!
//! Computed in `O(ℓ)` (independent of `w`) with Lemire's monotonic-deque
//! streaming min/max [Lemire 2009], which is what gives the whole bound
//! family its "constant complexity with respect to window size" property.
//!
//! `LB_WEBB` additionally uses *envelopes of envelopes*
//! (`𝕌^{𝕃^B}`, `𝕃^{𝕌^B}`) — just the same routine applied twice.

/// Compute lower and upper envelopes of `s` for window `w` into the
/// provided buffers (resized as needed). `O(ℓ)` via monotonic deques.
///
/// The deques are flat index rings in a thread-local scratch allocation —
/// `VecDeque` showed up at ~17% of NN-search profiles from per-call
/// allocation and wrap-around arithmetic (§Perf O2 in EXPERIMENTS.md).
pub fn envelopes_into(s: &[f64], w: usize, lo: &mut Vec<f64>, up: &mut Vec<f64>) {
    let n = s.len();
    assert!(n > 0, "envelope of empty series");
    lo.clear();
    up.clear();
    lo.resize(n, 0.0);
    up.resize(n, 0.0);
    if w == 0 {
        lo.copy_from_slice(s);
        up.copy_from_slice(s);
        return;
    }

    thread_local! {
        static IDX: std::cell::RefCell<Vec<u32>> = const { std::cell::RefCell::new(Vec::new()) };
    }
    IDX.with(|cell| {
        let mut buf = cell.borrow_mut();
        buf.clear();
        buf.resize(2 * n, 0);
        let (max_q, min_q) = buf.split_at_mut(n);
        // Plain head/tail cursors into the two index arrays. A deque
        // index enters at the tail monotone in value and expires at the
        // head when it leaves the window — no wrap-around ever occurs
        // because indices are strictly increasing and at most n live.
        let (mut max_h, mut max_t) = (0usize, 0usize); // [h, t) live
        let (mut min_h, mut min_t) = (0usize, 0usize);

        let mut admit = |j: usize,
                         max_q: &mut [u32],
                         min_q: &mut [u32],
                         max_h: &usize,
                         max_t: &mut usize,
                         min_h: &usize,
                         min_t: &mut usize| {
            let v = s[j];
            while *max_t > *max_h && s[max_q[*max_t - 1] as usize] <= v {
                *max_t -= 1;
            }
            max_q[*max_t] = j as u32;
            *max_t += 1;
            while *min_t > *min_h && s[min_q[*min_t - 1] as usize] >= v {
                *min_t -= 1;
            }
            min_q[*min_t] = j as u32;
            *min_t += 1;
        };

        // Prime with the first window [0, min(w, n-1)].
        for j in 0..=w.min(n - 1) {
            admit(j, max_q, min_q, &max_h, &mut max_t, &min_h, &mut min_t);
        }
        up[0] = s[max_q[max_h] as usize];
        lo[0] = s[min_q[min_h] as usize];

        for i in 1..n {
            // Admit the new right edge j = i + w.
            let j = i + w;
            if j < n {
                admit(j, max_q, min_q, &max_h, &mut max_t, &min_h, &mut min_t);
            }
            // Expire the old left edge j = i - w - 1.
            if i > w {
                let expired = (i - w - 1) as u32;
                if max_q[max_h] == expired {
                    max_h += 1;
                }
                if min_q[min_h] == expired {
                    min_h += 1;
                }
            }
            up[i] = s[max_q[max_h] as usize];
            lo[i] = s[min_q[min_h] as usize];
        }
    });
}

/// Convenience allocating wrapper around [`envelopes_into`]:
/// returns `(lower, upper)`.
pub fn envelopes(s: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    let mut lo = Vec::new();
    let mut up = Vec::new();
    envelopes_into(s, w, &mut lo, &mut up);
    (lo, up)
}

/// Naive `O(ℓ·w)` reference used by tests.
pub fn envelopes_naive(s: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    let n = s.len();
    let mut lo = vec![0.0; n];
    let mut up = vec![0.0; n];
    for i in 0..n {
        let a = i.saturating_sub(w);
        let b = (i + w).min(n - 1);
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for j in a..=b {
            mn = mn.min(s[j]);
            mx = mx.max(s[j]);
        }
        lo[i] = mn;
        up[i] = mx;
    }
    (lo, up)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn matches_naive_on_random_series() {
        let mut rng = Rng::seeded(42);
        for &n in &[1usize, 2, 3, 5, 17, 64, 257] {
            for &w in &[0usize, 1, 2, 3, 7, 50, 1000] {
                let s: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
                let (lo_f, up_f) = envelopes(&s, w);
                let (lo_n, up_n) = envelopes_naive(&s, w);
                assert_eq!(lo_f, lo_n, "lo n={n} w={w}");
                assert_eq!(up_f, up_n, "up n={n} w={w}");
            }
        }
    }

    #[test]
    fn envelope_sandwiches_series() {
        let mut rng = Rng::seeded(7);
        let s: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        for w in [0, 1, 5, 20] {
            let (lo, up) = envelopes(&s, w);
            for i in 0..s.len() {
                assert!(lo[i] <= s[i] && s[i] <= up[i]);
            }
        }
    }

    #[test]
    fn window_zero_is_identity() {
        let s = [3.0, -1.0, 4.0];
        let (lo, up) = envelopes(&s, 0);
        assert_eq!(lo, s.to_vec());
        assert_eq!(up, s.to_vec());
    }

    #[test]
    fn window_full_is_global_extrema() {
        let s = [3.0, -1.0, 4.0, 0.5];
        let (lo, up) = envelopes(&s, 10);
        assert!(lo.iter().all(|&v| v == -1.0));
        assert!(up.iter().all(|&v| v == 4.0));
    }

    #[test]
    fn envelopes_widen_with_window() {
        let mut rng = Rng::seeded(13);
        let s: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let mut prev = envelopes(&s, 0);
        for w in 1..12 {
            let cur = envelopes(&s, w);
            for i in 0..s.len() {
                assert!(cur.0[i] <= prev.0[i] && cur.1[i] >= prev.1[i]);
            }
            prev = cur;
        }
    }

    #[test]
    fn envelope_of_envelope_nests() {
        // 𝕃^{𝕌^B} lies between 𝕃^B-ish bounds: L_i <= LUB_i <= U_i etc.
        let mut rng = Rng::seeded(99);
        let s: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        for w in [1usize, 3, 9] {
            let (lo, up) = envelopes(&s, w);
            let (lub, _) = envelopes(&up, w);
            let (_, ulb) = envelopes(&lo, w);
            for i in 0..s.len() {
                assert!(lub[i] <= up[i] + 1e-15);
                assert!(ulb[i] >= lo[i] - 1e-15);
                // The key LB_Webb fact: within j's window every U_i >= LUB_j.
                let a = i.saturating_sub(w);
                let b = (i + w).min(s.len() - 1);
                for j in a..=b {
                    assert!(lub[i] <= up[j] + 1e-15);
                    assert!(ulb[i] >= lo[j] - 1e-15);
                }
            }
        }
    }
}
