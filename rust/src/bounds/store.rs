//! [`EnvelopeStore`] — flat, 64-byte-aligned structure-of-arrays storage
//! for a training set's warping envelopes.
//!
//! The per-series [`super::PreparedSeries`] layout is right for the
//! scalar search path (one candidate at a time, everything about it
//! together), but wrong for the *batched* screening path: scoring a
//! query against hundreds of candidates pointer-chases a fresh pair of
//! heap `Vec`s per candidate. The store packs every lower-envelope row
//! contiguously, then every upper-envelope row, into **one allocation**
//! whose rows start on 64-byte (cache-line) boundaries:
//!
//! ```text
//! [ lo(t0) pad ][ lo(t1) pad ] … [ lo(tn-1) pad ][ up(t0) pad ] …
//!   ^stride f64s, 64-byte aligned rows
//! ```
//!
//! so `lb_keogh` streams two sequential rows per pair — no per-pair
//! pointer indirection, no partial cache lines, hardware-prefetch
//! friendly. Values are copied out of the prepared series once per
//! index build ([`EnvelopeStore::rebuild`] reuses the allocation).

use super::PreparedSeries;

/// One cache line of f64s; a `Vec<CacheLine>` is 64-byte aligned, which
/// is what keeps every envelope row aligned without a custom allocator.
#[repr(C, align(64))]
#[derive(Debug, Clone, Copy)]
struct CacheLine([f64; 8]);

const LANE: usize = 8;

/// Flat SoA envelope storage: all `lo` rows contiguous, then all `up`
/// rows, one 64-byte-aligned allocation for the whole training set.
#[derive(Debug, Clone, Default)]
pub struct EnvelopeStore {
    /// Number of series.
    n: usize,
    /// Series length ℓ.
    l: usize,
    /// Row stride in f64s (ℓ rounded up to a multiple of 8).
    stride: usize,
    /// Backing allocation, `2 * n * stride / 8` cache lines.
    buf: Vec<CacheLine>,
}

impl EnvelopeStore {
    /// An empty store (no allocation).
    pub fn new() -> EnvelopeStore {
        EnvelopeStore::default()
    }

    /// Build a store from prepared series (all sharing one length).
    pub fn build(train: &[PreparedSeries]) -> EnvelopeStore {
        let mut store = EnvelopeStore::new();
        store.rebuild(train);
        store
    }

    /// (Re)populate from `train`, reusing the allocation when it is
    /// already large enough. Series must share one length.
    pub fn rebuild(&mut self, train: &[PreparedSeries]) {
        let n = train.len();
        let l = train.first().map(|t| t.len()).unwrap_or(0);
        debug_assert!(train.iter().all(|t| t.len() == l), "one shared length");
        let stride = l.div_ceil(LANE) * LANE;
        let lines = 2 * n * stride / LANE;
        self.n = n;
        self.l = l;
        self.stride = stride;
        // Zero-fill (cheap, and pad lanes never hold stale data).
        self.buf.clear();
        self.buf.resize(lines.max(1), CacheLine([0.0; LANE]));
        let flat = self.flat_mut();
        for (t, series) in train.iter().enumerate() {
            flat[t * stride..t * stride + l].copy_from_slice(&series.lo);
            flat[(n + t) * stride..(n + t) * stride + l].copy_from_slice(&series.up);
        }
    }

    /// Number of stored series.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when nothing is stored.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Series length ℓ.
    #[inline]
    pub fn series_len(&self) -> usize {
        self.l
    }

    /// Row stride in f64s (a multiple of 8; `stride - series_len()` pad
    /// elements per row).
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Lower-envelope row of series `t` (length ℓ, 64-byte aligned).
    #[inline]
    pub fn lo_row(&self, t: usize) -> &[f64] {
        debug_assert!(t < self.n);
        let start = t * self.stride;
        &self.flat()[start..start + self.l]
    }

    /// Upper-envelope row of series `t` (length ℓ, 64-byte aligned).
    #[inline]
    pub fn up_row(&self, t: usize) -> &[f64] {
        debug_assert!(t < self.n);
        let start = (self.n + t) * self.stride;
        &self.flat()[start..start + self.l]
    }

    #[inline]
    fn flat(&self) -> &[f64] {
        // Sound: `CacheLine` is `repr(C)` over `[f64; 8]`, so the buffer
        // is exactly `8 * buf.len()` contiguous, initialized f64s.
        unsafe {
            std::slice::from_raw_parts(self.buf.as_ptr() as *const f64, self.buf.len() * LANE)
        }
    }

    #[inline]
    fn flat_mut(&mut self) -> &mut [f64] {
        unsafe {
            std::slice::from_raw_parts_mut(
                self.buf.as_mut_ptr() as *mut f64,
                self.buf.len() * LANE,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn series(rng: &mut Rng, n: usize, l: usize, w: usize) -> Vec<PreparedSeries> {
        (0..n)
            .map(|_| PreparedSeries::prepare((0..l).map(|_| rng.normal()).collect(), w))
            .collect()
    }

    #[test]
    fn rows_match_prepared_series() {
        let mut rng = Rng::seeded(77);
        for &(n, l, w) in &[(1usize, 1usize, 0usize), (3, 7, 1), (5, 8, 2), (16, 129, 5)] {
            let train = series(&mut rng, n, l, w);
            let store = EnvelopeStore::build(&train);
            assert_eq!(store.len(), n);
            assert_eq!(store.series_len(), l);
            assert_eq!(store.stride() % 8, 0);
            assert!(store.stride() >= l);
            for (t, s) in train.iter().enumerate() {
                assert_eq!(store.lo_row(t), s.lo.as_slice(), "lo n={n} l={l} t={t}");
                assert_eq!(store.up_row(t), s.up.as_slice(), "up n={n} l={l} t={t}");
            }
        }
    }

    #[test]
    fn rows_are_cache_line_aligned() {
        let mut rng = Rng::seeded(78);
        let train = series(&mut rng, 4, 100, 3);
        let store = EnvelopeStore::build(&train);
        for t in 0..store.len() {
            assert_eq!(store.lo_row(t).as_ptr() as usize % 64, 0, "lo row {t}");
            assert_eq!(store.up_row(t).as_ptr() as usize % 64, 0, "up row {t}");
        }
    }

    #[test]
    fn rebuild_reuses_and_handles_shrink_and_empty() {
        let mut rng = Rng::seeded(79);
        let big = series(&mut rng, 8, 64, 2);
        let mut store = EnvelopeStore::build(&big);
        let small = series(&mut rng, 2, 16, 1);
        store.rebuild(&small);
        assert_eq!(store.len(), 2);
        assert_eq!(store.series_len(), 16);
        for (t, s) in small.iter().enumerate() {
            assert_eq!(store.lo_row(t), s.lo.as_slice());
            assert_eq!(store.up_row(t), s.up.as_slice());
        }
        store.rebuild(&[]);
        assert!(store.is_empty());
    }
}
