//! [`EnvelopeStore`] — flat, 64-byte-aligned structure-of-arrays storage
//! for a training set's warping envelopes.
//!
//! The per-series [`super::PreparedSeries`] layout is right for the
//! scalar search path (one candidate at a time, everything about it
//! together), but wrong for the *batched* screening path: scoring a
//! query against hundreds of candidates pointer-chases a fresh pair of
//! heap `Vec`s per candidate. The store packs every lower-envelope row
//! contiguously, then every upper-envelope row, into **one allocation**
//! whose rows start on 64-byte (cache-line) boundaries:
//!
//! ```text
//! [ lo(t0) pad ][ lo(t1) pad ] … [ lo(tn-1) pad ][ up(t0) pad ] …
//!   ^stride f64s, 64-byte aligned rows
//! ```
//!
//! so `lb_keogh` streams two sequential rows per pair — no per-pair
//! pointer indirection, no partial cache lines, hardware-prefetch
//! friendly. Values are copied out of the prepared series once per
//! index build ([`EnvelopeStore::rebuild`] reuses the allocation).
//!
//! The 64-byte alignment is a *throughput* property, never a safety
//! precondition: the SIMD kernels ([`crate::simd`]) use unaligned
//! loads throughout and accept arbitrary sub-slices (the differential
//! suite deliberately feeds them odd offsets), so aligned rows simply
//! avoid cache-line splits on the batch path.
//!
//! The flat layout is also the crate's **persistence payload**: a
//! snapshot stores each shard's padded buffer verbatim
//! ([`EnvelopeStore::payload`]) so that loading is a length check plus
//! one bulk copy back into a fresh 64-byte-aligned allocation
//! ([`EnvelopeStore::from_payload`]) — no per-series re-preparation on
//! the cold-start path. [`ShardStore`] pairs a store with the global
//! candidate range it owns; [`partition_shards`] cuts a training set
//! into the contiguous per-shard stores the sharded search and the
//! snapshot format both consume.

use std::ops::Range;

use super::PreparedSeries;

/// One cache line of f64s; a `Vec<CacheLine>` is 64-byte aligned, which
/// is what keeps every envelope row aligned without a custom allocator.
#[repr(C, align(64))]
#[derive(Debug, Clone, Copy)]
struct CacheLine([f64; 8]);

const LANE: usize = 8;

/// Flat SoA envelope storage: all `lo` rows contiguous, then all `up`
/// rows, one 64-byte-aligned allocation for the whole training set.
#[derive(Debug, Clone, Default)]
pub struct EnvelopeStore {
    /// Number of series.
    n: usize,
    /// Series length ℓ.
    l: usize,
    /// Row stride in f64s (ℓ rounded up to a multiple of 8).
    stride: usize,
    /// Backing allocation, `2 * n * stride / 8` cache lines.
    buf: Vec<CacheLine>,
}

impl EnvelopeStore {
    /// An empty store (no allocation).
    pub fn new() -> EnvelopeStore {
        EnvelopeStore::default()
    }

    /// Build a store from prepared series (all sharing one length).
    pub fn build(train: &[PreparedSeries]) -> EnvelopeStore {
        let mut store = EnvelopeStore::new();
        store.rebuild(train);
        store
    }

    /// (Re)populate from `train`, reusing the allocation when it is
    /// already large enough. Series must share one length.
    pub fn rebuild(&mut self, train: &[PreparedSeries]) {
        let n = train.len();
        let l = train.first().map(|t| t.len()).unwrap_or(0);
        debug_assert!(train.iter().all(|t| t.len() == l), "one shared length");
        let stride = l.div_ceil(LANE) * LANE;
        let lines = 2 * n * stride / LANE;
        self.n = n;
        self.l = l;
        self.stride = stride;
        // Zero-fill (cheap, and pad lanes never hold stale data).
        self.buf.clear();
        self.buf.resize(lines.max(1), CacheLine([0.0; LANE]));
        let flat = self.flat_mut();
        for (t, series) in train.iter().enumerate() {
            flat[t * stride..t * stride + l].copy_from_slice(&series.lo);
            flat[(n + t) * stride..(n + t) * stride + l].copy_from_slice(&series.up);
        }
    }

    /// Number of stored series.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when nothing is stored.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Series length ℓ.
    #[inline]
    pub fn series_len(&self) -> usize {
        self.l
    }

    /// Row stride in f64s (a multiple of 8; `stride - series_len()` pad
    /// elements per row).
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// The row stride any store uses for series length `l` — what the
    /// snapshot format records and validates against.
    #[inline]
    pub fn stride_for(l: usize) -> usize {
        l.div_ceil(LANE) * LANE
    }

    /// Lower-envelope row of series `t` (length ℓ, 64-byte aligned).
    #[inline]
    pub fn lo_row(&self, t: usize) -> &[f64] {
        debug_assert!(t < self.n);
        let start = t * self.stride;
        &self.flat()[start..start + self.l]
    }

    /// Upper-envelope row of series `t` (length ℓ, 64-byte aligned).
    #[inline]
    pub fn up_row(&self, t: usize) -> &[f64] {
        debug_assert!(t < self.n);
        let start = (self.n + t) * self.stride;
        &self.flat()[start..start + self.l]
    }

    /// The padded flat payload — all `lo` rows then all `up` rows,
    /// exactly `2 * len() * stride()` f64s (pad lanes are zero). This is
    /// what the snapshot format serializes; [`EnvelopeStore::from_payload`]
    /// restores it with one bulk copy.
    #[inline]
    pub fn payload(&self) -> &[f64] {
        &self.flat()[..2 * self.n * self.stride]
    }

    /// Build a store from raw `lo`/`up` rows (one pair per stored row,
    /// all sharing one length) — the merged cluster-envelope path,
    /// where rows are synthesized instead of coming from prepared
    /// series. Layout and alignment match [`EnvelopeStore::build`].
    pub fn from_rows(lo_rows: &[Vec<f64>], up_rows: &[Vec<f64>]) -> EnvelopeStore {
        debug_assert_eq!(lo_rows.len(), up_rows.len(), "one lo per up row");
        let n = lo_rows.len();
        let l = lo_rows.first().map(|r| r.len()).unwrap_or(0);
        debug_assert!(lo_rows.iter().chain(up_rows).all(|r| r.len() == l), "one shared length");
        let stride = l.div_ceil(LANE) * LANE;
        let lines = 2 * n * stride / LANE;
        let mut store = EnvelopeStore {
            n,
            l,
            stride,
            buf: vec![CacheLine([0.0; LANE]); lines.max(1)],
        };
        let flat = store.flat_mut();
        for (t, row) in lo_rows.iter().enumerate() {
            flat[t * stride..t * stride + l].copy_from_slice(row);
        }
        for (t, row) in up_rows.iter().enumerate() {
            flat[(n + t) * stride..(n + t) * stride + l].copy_from_slice(row);
        }
        store
    }

    /// Rebuild a store from a padded flat payload (the inverse of
    /// [`EnvelopeStore::payload`]): a length check, a fresh 64-byte-
    /// aligned allocation, and one bulk copy. Errors when the payload
    /// size does not match `2 * n * stride(l)`.
    pub fn from_payload(n: usize, l: usize, payload: &[f64]) -> Result<EnvelopeStore, String> {
        let mut store = EnvelopeStore::sized(n, l, payload.len())?;
        let want = 2 * n * store.stride;
        store.flat_mut()[..want].copy_from_slice(payload);
        Ok(store)
    }

    /// [`EnvelopeStore::from_payload`] straight from little-endian
    /// bytes (8 per f64, raw bits): the snapshot loader's path —
    /// decodes directly into the fresh aligned allocation, with no
    /// intermediate `Vec<f64>`.
    pub fn from_le_payload(n: usize, l: usize, bytes: &[u8]) -> Result<EnvelopeStore, String> {
        if bytes.len() % 8 != 0 {
            return Err(format!("envelope payload of {} bytes is not 8-aligned", bytes.len()));
        }
        let mut store = EnvelopeStore::sized(n, l, bytes.len() / 8)?;
        let want = 2 * n * store.stride;
        for (slot, chunk) in store.flat_mut()[..want].iter_mut().zip(bytes.chunks_exact(8)) {
            *slot = f64::from_bits(u64::from_le_bytes(chunk.try_into().expect("8 bytes")));
        }
        Ok(store)
    }

    /// Shared shape validation + aligned allocation for the payload
    /// constructors: `values` is the payload length in f64s.
    fn sized(n: usize, l: usize, values: usize) -> Result<EnvelopeStore, String> {
        let stride = l.div_ceil(LANE) * LANE;
        let want = 2 * n * stride;
        if values != want {
            return Err(format!(
                "envelope payload holds {values} values, expected {want} \
                 (n={n}, l={l}, stride={stride})"
            ));
        }
        Ok(EnvelopeStore { n, l, stride, buf: vec![CacheLine([0.0; LANE]); (want / LANE).max(1)] })
    }

    #[inline]
    fn flat(&self) -> &[f64] {
        // Sound: `CacheLine` is `repr(C)` over `[f64; 8]`, so the buffer
        // is exactly `8 * buf.len()` contiguous, initialized f64s.
        unsafe {
            std::slice::from_raw_parts(self.buf.as_ptr() as *const f64, self.buf.len() * LANE)
        }
    }

    #[inline]
    fn flat_mut(&mut self) -> &mut [f64] {
        unsafe {
            std::slice::from_raw_parts_mut(
                self.buf.as_mut_ptr() as *mut f64,
                self.buf.len() * LANE,
            )
        }
    }
}

/// Cluster-pruning metadata for one shard: the shard's candidates
/// grouped around pivots, with one **merged envelope** per cluster
/// (elementwise min of member `lo` rows / max of member `up` rows).
///
/// The merged envelope *contains* every member's envelope, so
/// `LB_KEOGH(query, merged) ≤ LB_KEOGH(query, member) ≤ DTW(query,
/// member)` for every member — one envelope-vs-query bound per cluster
/// is a valid lower bound on every member's distance, which is what
/// lets the search kernels skip whole clusters exactly (see
/// ARCHITECTURE.md "Sublinear pruning" for the proof). Per-member pivot
/// distances (fixed-cutoff exact DTW at build time) order members
/// near-pivot-first inside each cluster; they are advisory only — DTW
/// is not a metric, so no triangle-inequality *skip* is derived from
/// them.
#[derive(Debug, Clone, Default)]
pub struct ShardClusters {
    /// Member local offsets grouped by cluster: cluster `c` owns
    /// `members[offsets[c]..offsets[c+1]]`, ordered ascending by
    /// `(pivot distance, offset)`.
    members: Vec<u32>,
    /// Cluster boundaries into `members` (length = cluster count + 1).
    offsets: Vec<u32>,
    /// Each cluster's pivot, as a member local offset.
    pivots: Vec<u32>,
    /// Per member local offset: exact DTW distance to its cluster's
    /// pivot under the build-time fixed cutoff (`INFINITY` when the
    /// computation was abandoned at that cutoff).
    pivot_dist: Vec<f64>,
    /// Merged cluster envelopes; row `c` is cluster `c`'s min-lo/max-up.
    env: EnvelopeStore,
}

impl ShardClusters {
    /// Assemble (and validate) cluster metadata for a shard of
    /// `shard_len` candidates. Errors describe the first violated
    /// invariant — the snapshot loader surfaces them as corruption.
    pub fn from_parts(
        shard_len: usize,
        members: Vec<u32>,
        offsets: Vec<u32>,
        pivots: Vec<u32>,
        pivot_dist: Vec<f64>,
        env: EnvelopeStore,
    ) -> Result<ShardClusters, String> {
        let k = pivots.len();
        if offsets.len() != k + 1 {
            return Err(format!("{} offsets for {k} clusters, expected {}", offsets.len(), k + 1));
        }
        if members.len() != shard_len {
            return Err(format!("{} members for a {shard_len}-candidate shard", members.len()));
        }
        if pivot_dist.len() != shard_len {
            return Err(format!(
                "{} pivot distances for a {shard_len}-candidate shard",
                pivot_dist.len()
            ));
        }
        if env.len() != k {
            return Err(format!("{} merged envelopes for {k} clusters", env.len()));
        }
        if offsets.first() != Some(&0) || offsets.last() != Some(&(shard_len as u32)) {
            return Err("cluster offsets must start at 0 and end at the shard length".into());
        }
        let mut seen = vec![false; shard_len];
        for win in offsets.windows(2) {
            if win[0] >= win[1] {
                return Err(format!("empty or unordered cluster at offsets {}..{}", win[0], win[1]));
            }
        }
        for &m in &members {
            let m = m as usize;
            if m >= shard_len || seen[m] {
                return Err(format!("member {m} out of range or repeated"));
            }
            seen[m] = true;
        }
        for (c, &p) in pivots.iter().enumerate() {
            let (a, b) = (offsets[c] as usize, offsets[c + 1] as usize);
            if !members[a..b].contains(&p) {
                return Err(format!("pivot {p} is not a member of its cluster {c}"));
            }
        }
        Ok(ShardClusters { members, offsets, pivots, pivot_dist, env })
    }

    /// Number of clusters.
    #[inline]
    pub fn len(&self) -> usize {
        self.pivots.len()
    }

    /// True when no clusters are held.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pivots.is_empty()
    }

    /// Cluster `c`'s member local offsets, ascending by
    /// `(pivot distance, offset)`.
    #[inline]
    pub fn members_of(&self, c: usize) -> &[u32] {
        &self.members[self.offsets[c] as usize..self.offsets[c + 1] as usize]
    }

    /// Cluster `c`'s pivot, as a member local offset.
    #[inline]
    pub fn pivot(&self, c: usize) -> usize {
        self.pivots[c] as usize
    }

    /// Member `local`'s build-time DTW distance to its cluster's pivot
    /// (`INFINITY` when abandoned at the fixed cutoff).
    #[inline]
    pub fn pivot_dist(&self, local: usize) -> f64 {
        self.pivot_dist[local]
    }

    /// The merged cluster envelopes (row `c` = cluster `c`).
    #[inline]
    pub fn env(&self) -> &EnvelopeStore {
        &self.env
    }

    /// The grouped member list (snapshot serialization).
    #[inline]
    pub fn members(&self) -> &[u32] {
        &self.members
    }

    /// The cluster boundaries (snapshot serialization).
    #[inline]
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// The pivot offsets (snapshot serialization).
    #[inline]
    pub fn pivots(&self) -> &[u32] {
        &self.pivots
    }

    /// The per-member pivot distances (snapshot serialization).
    #[inline]
    pub fn pivot_dists(&self) -> &[f64] {
        &self.pivot_dist
    }
}

/// One shard of a sharded index: a contiguous slice of the global
/// candidate set, owned as a flat [`EnvelopeStore`]. Shard `s` covers
/// global candidate ids `range()`; row `t` of the store is global
/// candidate `start() + t`. Contiguity is what makes sharded search
/// trivially bit-equal to serial: the union of the shard ranges *is*
/// the serial candidate order, and every kernel merges through a total
/// `(distance, index)` order. A shard may additionally carry
/// [`ShardClusters`] for cluster-level pruning; searches without them
/// fall back to the flat per-candidate sweep.
#[derive(Debug, Clone, Default)]
pub struct ShardStore {
    start: usize,
    store: EnvelopeStore,
    clusters: Option<ShardClusters>,
}

impl ShardStore {
    /// A shard covering global candidates `start .. start + store.len()`
    /// with no cluster metadata.
    pub fn new(start: usize, store: EnvelopeStore) -> ShardStore {
        ShardStore { start, store, clusters: None }
    }

    /// Attach cluster-pruning metadata (builder and snapshot loader).
    pub fn with_clusters(mut self, clusters: ShardClusters) -> ShardStore {
        debug_assert_eq!(clusters.members.len(), self.store.len(), "clusters cover the shard");
        self.clusters = Some(clusters);
        self
    }

    /// Cluster-pruning metadata, when the index was built with it.
    #[inline]
    pub fn clusters(&self) -> Option<&ShardClusters> {
        self.clusters.as_ref()
    }

    /// First global candidate id this shard owns.
    #[inline]
    pub fn start(&self) -> usize {
        self.start
    }

    /// Number of candidates in this shard.
    #[inline]
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// True when the shard owns no candidates.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Global candidate ids owned by this shard.
    #[inline]
    pub fn range(&self) -> Range<usize> {
        self.start..self.start + self.store.len()
    }

    /// The shard's flat envelope store (row `t` = global candidate
    /// `start() + t`).
    #[inline]
    pub fn store(&self) -> &EnvelopeStore {
        &self.store
    }
}

/// Cut `train` into `shards` contiguous [`ShardStore`]s (deterministic:
/// the first `n % shards` shards get one extra candidate, so shard
/// sizes differ by at most one and the partition depends only on
/// `(n, shards)`). `shards` is clamped to `1..=n`; an empty training
/// set yields no shards.
pub fn partition_shards(train: &[PreparedSeries], shards: usize) -> Vec<ShardStore> {
    let n = train.len();
    if n == 0 {
        return Vec::new();
    }
    let shards = shards.clamp(1, n);
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push(ShardStore::new(start, EnvelopeStore::build(&train[start..start + len])));
        start += len;
    }
    debug_assert_eq!(start, n, "shards cover every candidate exactly once");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn series(rng: &mut Rng, n: usize, l: usize, w: usize) -> Vec<PreparedSeries> {
        (0..n)
            .map(|_| PreparedSeries::prepare((0..l).map(|_| rng.normal()).collect(), w))
            .collect()
    }

    #[test]
    fn rows_match_prepared_series() {
        let mut rng = Rng::seeded(77);
        for &(n, l, w) in &[(1usize, 1usize, 0usize), (3, 7, 1), (5, 8, 2), (16, 129, 5)] {
            let train = series(&mut rng, n, l, w);
            let store = EnvelopeStore::build(&train);
            assert_eq!(store.len(), n);
            assert_eq!(store.series_len(), l);
            assert_eq!(store.stride() % 8, 0);
            assert!(store.stride() >= l);
            for (t, s) in train.iter().enumerate() {
                assert_eq!(store.lo_row(t), s.lo.as_slice(), "lo n={n} l={l} t={t}");
                assert_eq!(store.up_row(t), s.up.as_slice(), "up n={n} l={l} t={t}");
            }
        }
    }

    #[test]
    fn rows_are_cache_line_aligned() {
        let mut rng = Rng::seeded(78);
        let train = series(&mut rng, 4, 100, 3);
        let store = EnvelopeStore::build(&train);
        for t in 0..store.len() {
            assert_eq!(store.lo_row(t).as_ptr() as usize % 64, 0, "lo row {t}");
            assert_eq!(store.up_row(t).as_ptr() as usize % 64, 0, "up row {t}");
        }
    }

    #[test]
    fn rebuild_reuses_and_handles_shrink_and_empty() {
        let mut rng = Rng::seeded(79);
        let big = series(&mut rng, 8, 64, 2);
        let mut store = EnvelopeStore::build(&big);
        let small = series(&mut rng, 2, 16, 1);
        store.rebuild(&small);
        assert_eq!(store.len(), 2);
        assert_eq!(store.series_len(), 16);
        for (t, s) in small.iter().enumerate() {
            assert_eq!(store.lo_row(t), s.lo.as_slice());
            assert_eq!(store.up_row(t), s.up.as_slice());
        }
        store.rebuild(&[]);
        assert!(store.is_empty());
    }

    #[test]
    fn payload_round_trips_bit_exactly() {
        let mut rng = Rng::seeded(80);
        for &(n, l, w) in &[(0usize, 0usize, 0usize), (1, 5, 1), (4, 33, 3), (7, 64, 2)] {
            let train = series(&mut rng, n, l, w);
            let store = EnvelopeStore::build(&train);
            let payload = store.payload().to_vec();
            assert_eq!(payload.len(), 2 * n * store.stride());
            let restored = EnvelopeStore::from_payload(n, l, &payload).unwrap();
            assert_eq!(restored.len(), store.len());
            assert_eq!(restored.series_len(), store.series_len());
            assert_eq!(restored.stride(), store.stride());
            for t in 0..n {
                assert_eq!(restored.lo_row(t), store.lo_row(t), "lo n={n} l={l} t={t}");
                assert_eq!(restored.up_row(t), store.up_row(t), "up n={n} l={l} t={t}");
                assert_eq!(restored.lo_row(t).as_ptr() as usize % 64, 0, "alignment survives");
            }
            // The byte-decoding constructor agrees bit-for-bit.
            let bytes: Vec<u8> =
                payload.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect();
            let from_bytes = EnvelopeStore::from_le_payload(n, l, &bytes).unwrap();
            for t in 0..n {
                assert_eq!(from_bytes.lo_row(t), store.lo_row(t), "le lo n={n} l={l} t={t}");
                assert_eq!(from_bytes.up_row(t), store.up_row(t), "le up n={n} l={l} t={t}");
            }
            assert!(EnvelopeStore::from_le_payload(n, l, &bytes[..bytes.len() / 2]).is_err()
                || n == 0);
        }
    }

    #[test]
    fn from_payload_rejects_wrong_sizes() {
        let mut rng = Rng::seeded(81);
        let train = series(&mut rng, 3, 10, 1);
        let store = EnvelopeStore::build(&train);
        let mut payload = store.payload().to_vec();
        payload.pop();
        assert!(EnvelopeStore::from_payload(3, 10, &payload).is_err());
        assert!(EnvelopeStore::from_payload(2, 10, store.payload()).is_err());
        assert!(EnvelopeStore::from_payload(3, 11, store.payload()).is_err());
    }

    #[test]
    fn from_rows_matches_build_layout() {
        let mut rng = Rng::seeded(83);
        let train = series(&mut rng, 5, 37, 2);
        let lo: Vec<Vec<f64>> = train.iter().map(|t| t.lo.clone()).collect();
        let up: Vec<Vec<f64>> = train.iter().map(|t| t.up.clone()).collect();
        let a = EnvelopeStore::build(&train);
        let b = EnvelopeStore::from_rows(&lo, &up);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.stride(), b.stride());
        for t in 0..a.len() {
            assert_eq!(a.lo_row(t), b.lo_row(t));
            assert_eq!(a.up_row(t), b.up_row(t));
            assert_eq!(b.lo_row(t).as_ptr() as usize % 64, 0, "aligned");
        }
        assert!(EnvelopeStore::from_rows(&[], &[]).is_empty());
    }

    #[test]
    fn shard_clusters_validate_and_expose_groups() {
        let mut rng = Rng::seeded(84);
        let train = series(&mut rng, 4, 16, 2);
        let env = EnvelopeStore::build(&train[..2]);
        // Two clusters over a 4-candidate shard: {1, 0} and {2, 3}.
        let cl = ShardClusters::from_parts(
            4,
            vec![1, 0, 2, 3],
            vec![0, 2, 4],
            vec![1, 2],
            vec![0.5, 0.0, 0.0, 2.0],
            env.clone(),
        )
        .unwrap();
        assert_eq!(cl.len(), 2);
        assert_eq!(cl.members_of(0), &[1, 0]);
        assert_eq!(cl.members_of(1), &[2, 3]);
        assert_eq!(cl.pivot(0), 1);
        assert_eq!(cl.pivot_dist(3), 2.0);
        assert_eq!(cl.env().len(), 2);

        // Each invariant violation is rejected, not panicked on.
        let bad = [
            // offsets mismatch cluster count
            ShardClusters::from_parts(4, vec![1, 0, 2, 3], vec![0, 4], vec![1, 2], vec![0.0; 4], env.clone()),
            // members not a permutation
            ShardClusters::from_parts(4, vec![1, 1, 2, 3], vec![0, 2, 4], vec![1, 2], vec![0.0; 4], env.clone()),
            // empty cluster
            ShardClusters::from_parts(4, vec![1, 0, 2, 3], vec![0, 0, 4], vec![1, 2], vec![0.0; 4], env.clone()),
            // pivot outside its cluster
            ShardClusters::from_parts(4, vec![1, 0, 2, 3], vec![0, 2, 4], vec![3, 2], vec![0.0; 4], env.clone()),
            // wrong envelope count
            ShardClusters::from_parts(4, vec![1, 0, 2, 3], vec![0, 2, 4], vec![1, 2], vec![0.0; 4], EnvelopeStore::build(&train[..3])),
            // wrong pivot-distance count
            ShardClusters::from_parts(4, vec![1, 0, 2, 3], vec![0, 2, 4], vec![1, 2], vec![0.0; 3], env.clone()),
        ];
        for (i, r) in bad.into_iter().enumerate() {
            assert!(r.is_err(), "case {i} should be rejected");
        }
    }

    #[test]
    fn partition_covers_every_candidate_once() {
        let mut rng = Rng::seeded(82);
        for &(n, shards) in &[(1usize, 1usize), (5, 2), (10, 3), (10, 7), (4, 9), (12, 1)] {
            let train = series(&mut rng, n, 16, 2);
            let parts = partition_shards(&train, shards);
            assert_eq!(parts.len(), shards.clamp(1, n), "n={n} shards={shards}");
            let mut next = 0usize;
            for p in &parts {
                assert_eq!(p.start(), next, "contiguous");
                assert!(!p.is_empty());
                for (t_local, t_global) in p.range().enumerate() {
                    assert_eq!(p.store().lo_row(t_local), train[t_global].lo.as_slice());
                    assert_eq!(p.store().up_row(t_local), train[t_global].up.as_slice());
                }
                next = p.range().end;
            }
            assert_eq!(next, n, "full coverage");
            // Sizes differ by at most one.
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "balanced: {sizes:?}");
        }
        assert!(partition_shards(&[], 4).is_empty());
    }
}
