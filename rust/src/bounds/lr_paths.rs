//! `MinLRPaths` — the left/right *path* mechanism of `LB_PETITJEAN` and
//! `LB_WEBB` (paper §4, Figure 11).
//!
//! The boundary conditions pin every warping path to `(1,1)` and `(ℓ,ℓ)`,
//! and the first/last three alignments can only take one of **seven**
//! shapes each (Figure 11). Summing `δ(A_1,B_1) + δ(A_ℓ,B_ℓ)` with the
//! minimum over those seven two-alignment continuations yields a bound on
//! the cost any path pays inside the first three and last three elements —
//! strictly tighter than `LB_ENHANCED`'s bands of the same depth, at
//! constant cost.
//!
//! We additionally filter options by the window (an alignment `(i,j)` with
//! `|i-j| > w` cannot occur), which both tightens the bound for `w = 1`
//! and keeps it sound for `w = 0` (only the diagonal option survives).

use crate::delta::Delta;

/// The seven start options of Figure 11, as 0-based `(i, j)` alignment
/// pairs for the second and third alignments (the first is always
/// `(0,0)`).
const START_OPTIONS: [[(usize, usize); 2]; 7] = [
    [(0, 1), (0, 2)],
    [(0, 1), (1, 2)],
    [(1, 1), (1, 2)],
    [(1, 1), (2, 2)],
    [(1, 1), (2, 1)],
    [(1, 0), (2, 1)],
    [(1, 0), (2, 0)],
];

#[inline]
fn within_window(p: (usize, usize), w: usize) -> bool {
    p.0.abs_diff(p.1) <= w
}

/// `MinLRPaths(A, B)` for window `w`. Requires `ℓ ≥ 6` so the start and
/// end regions are disjoint (callers fall back to the `NoLR` variants for
/// shorter series).
pub fn min_lr_paths<D: Delta>(a: &[f64], b: &[f64], w: usize) -> f64 {
    let n = a.len();
    debug_assert!(n >= 6 && b.len() == n, "MinLRPaths requires equal-length series, l >= 6");

    let mut start_min = f64::INFINITY;
    let mut end_min = f64::INFINITY;
    for opt in &START_OPTIONS {
        if within_window(opt[0], w) && within_window(opt[1], w) {
            let c = D::delta(a[opt[0].0], b[opt[0].1]) + D::delta(a[opt[1].0], b[opt[1].1]);
            if c < start_min {
                start_min = c;
            }
        }
        // The end options are the start options mirrored through
        // (ℓ-1, ℓ-1): alignment (i, j) ↦ (ℓ-1-i, ℓ-1-j).
        let m0 = (n - 1 - opt[0].0, n - 1 - opt[0].1);
        let m1 = (n - 1 - opt[1].0, n - 1 - opt[1].1);
        if within_window(m0, w) && within_window(m1, w) {
            let c = D::delta(a[m0.0], b[m0.1]) + D::delta(a[m1.0], b[m1.1]);
            if c < end_min {
                end_min = c;
            }
        }
    }
    // Option [(1,1),(2,2)] is always within any window, so the minima are
    // finite for every w ≥ 0.
    D::delta(a[0], b[0]) + D::delta(a[n - 1], b[n - 1]) + start_min + end_min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::delta::Squared;
    use crate::dtw::{cost_matrix, dtw, warping_path};

    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    #[test]
    fn is_a_lower_bound_alone() {
        for w in 0..A.len() {
            let lb = min_lr_paths::<Squared>(&A, &B, w);
            assert!(lb <= dtw::<Squared>(&A, &B, w) + 1e-12, "w={w}");
        }
    }

    #[test]
    fn window_zero_forces_diagonal() {
        let lb = min_lr_paths::<Squared>(&A, &B, 0);
        let diag = |i: usize| (A[i] - B[i]) * (A[i] - B[i]);
        assert_eq!(lb, diag(0) + diag(10) + diag(1) + diag(2) + diag(9) + diag(8));
    }

    #[test]
    fn bounds_the_actual_path_prefix_suffix() {
        // The cost of the first three + last three alignments of the true
        // optimal path must dominate MinLRPaths.
        let mut rng = Rng::seeded(401);
        for _ in 0..100 {
            let n = rng.int_range(8, 40);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for w in [1usize, 2, 3] {
                let m = cost_matrix::<Squared>(&a, &b, w);
                let p = warping_path(&m);
                let endpoint_cost: f64 = p[..3]
                    .iter()
                    .chain(p[p.len() - 3..].iter())
                    .map(|&(i, j)| (a[i] - b[j]) * (a[i] - b[j]))
                    .sum();
                let lb = min_lr_paths::<Squared>(&a, &b, w);
                assert!(lb <= endpoint_cost + 1e-9, "n={n} w={w}");
            }
        }
    }

    #[test]
    fn tighter_or_equal_with_larger_window_options() {
        // More options can only lower the min... so the bound is
        // non-increasing as w grows (option set grows).
        let mut last = f64::INFINITY;
        for w in 0..5 {
            let lb = min_lr_paths::<Squared>(&A, &B, w);
            assert!(lb <= last + 1e-12);
            last = lb;
        }
    }

    #[test]
    fn zero_on_identical_series() {
        assert_eq!(min_lr_paths::<Squared>(&A, &A, 2), 0.0);
    }
}
