//! The [`DtwIndex`] facade — **the crate's primary API**.
//!
//! The paper's whole pitch (§8, Algorithms 3–4) is that lower bounds are
//! *screening devices for nearest-neighbor search*. This module packages
//! that workflow the way the UCR suite does (index once, query many):
//!
//! ```
//! use dtw_bounds::delta::Squared;
//! use dtw_bounds::index::DtwIndex;
//!
//! let train = vec![
//!     vec![0.0, 0.1, 0.4, 0.2, 0.0, -0.2],
//!     vec![1.0, 0.9, 0.8, 0.9, 1.1, 1.0],
//!     vec![0.0, 0.5, 1.0, 0.5, 0.0, -0.5],
//! ];
//! let index = DtwIndex::builder(train).labels(vec![0, 1, 0]).window(1).build()?;
//! let outcome = index.knn::<Squared>(&[0.0, 0.2, 0.5, 0.2, 0.0, -0.3], 2);
//! assert_eq!(outcome.neighbors.len(), 2);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! * [`DtwIndex`] — immutable, cheaply cloneable (`Arc` inside), `Send +
//!   Sync`: the prepared envelopes plus the search configuration. Share
//!   one across threads; every layer (CLI, coordinator, benches,
//!   examples) consumes it.
//! * [`Searcher`] — a per-thread query handle owning the mutable state a
//!   search needs: scratch buffers, sort buffers, the random-order RNG
//!   and the optional batched [`LbBackend`] prefilter (backend handles,
//!   PJRT in particular, must not cross threads).
//! * [`Query`]/[`QueryOptions`]/[`QueryOutcome`] — typed k-NN requests
//!   (`k ≥ 1`, abandon threshold, z-norm policy, self-match exclusion)
//!   and results with per-stage pruning counts.
//!
//! Every path returns **exact** DTW nearest neighbors; strategies and
//! backends only move the screening cost.

mod builder;
mod query;
pub mod snapshot;

pub use builder::DtwIndexBuilder;
pub use query::{Neighbor, Query, QueryOptions, QueryOutcome};
pub use snapshot::{SnapshotError, SnapshotInfo};

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::bounds::store::ShardStore;
use crate::bounds::{BoundKind, Scratch};
use crate::data::rng::Rng;
use crate::data::znorm::znormalized;
use crate::data::Dataset;
use crate::delta::{Delta, Squared};
use crate::dtw::dtw_ea;
use crate::exec::Executor;
use crate::runtime::{BackendKind, LbBackend, NativeBatchLb, Ranking};
use crate::search::knn::{
    knn_brute_force, knn_parallel, knn_random_order, knn_sharded_stores, knn_sorted,
    knn_sorted_precomputed, KnnParams,
};
use crate::search::nn::NnResult;
use crate::search::{PreparedTrainSet, SearchStrategy};

/// Search configuration fixed at build time.
#[derive(Debug, Clone)]
pub(crate) struct IndexConfig {
    pub(crate) bound: BoundKind,
    pub(crate) strategy: SearchStrategy,
    pub(crate) backend: BackendKind,
    pub(crate) max_batch: usize,
    pub(crate) znorm: bool,
    pub(crate) seed: u64,
    pub(crate) threads: usize,
    /// Per-shard cluster target (`0` = no cluster pruning).
    pub(crate) clusters: usize,
    /// Live-mutation generation (incremented by each compaction; `0` is
    /// the frozen, never-compacted baseline).
    pub(crate) generation: u64,
    /// Generation this index was compacted from (`0` for the baseline).
    pub(crate) parent: u64,
}

/// An immutable DTW nearest-neighbor index: prepared training envelopes
/// plus search configuration. Cloning is cheap (the prepared data is
/// shared via `Arc`), and the handle is `Send + Sync` — share one across
/// threads and give each thread its own [`Searcher`].
#[derive(Debug, Clone)]
pub struct DtwIndex {
    pub(crate) train: Arc<PreparedTrainSet>,
    /// Contiguous per-shard flat envelope stores over the same
    /// candidates ([`crate::bounds::store::partition_shards`]) — the
    /// unit of search fan-out and the snapshot payload. One shard for
    /// an unsharded index; empty when the index is empty **or** the
    /// configuration never reads flat stores (single shard + non-store
    /// backend — the builder skips the copy; `save()` materializes a
    /// transient partition).
    pub(crate) shards: Arc<Vec<ShardStore>>,
    pub(crate) config: IndexConfig,
}

impl DtwIndex {
    /// Start building an index over a training corpus (one `Vec<f64>`
    /// per series; all series must share one length).
    pub fn builder(series: Vec<Vec<f64>>) -> DtwIndexBuilder {
        DtwIndexBuilder::new(series)
    }

    /// Start building from a dataset's training split (labels and the
    /// recommended window are pre-filled; override freely).
    pub fn builder_from_dataset(ds: &Dataset) -> DtwIndexBuilder {
        DtwIndexBuilder::from_dataset(ds)
    }

    /// The prepared training data.
    pub fn train(&self) -> &PreparedTrainSet {
        &self.train
    }

    /// Number of indexed series.
    pub fn len(&self) -> usize {
        self.train.len()
    }

    /// True when nothing is indexed.
    pub fn is_empty(&self) -> bool {
        self.train.is_empty()
    }

    /// The warping window.
    pub fn window(&self) -> usize {
        self.train.w
    }

    /// The screening bound.
    pub fn bound(&self) -> BoundKind {
        self.config.bound
    }

    /// The search strategy.
    pub fn strategy(&self) -> SearchStrategy {
        self.config.strategy
    }

    /// The backend kind new searchers instantiate.
    pub fn backend(&self) -> BackendKind {
        self.config.backend
    }

    /// Cap on how many queries ride one batched prefilter execution.
    pub fn max_batch(&self) -> usize {
        self.config.max_batch
    }

    /// The configured search thread count (`0` = machine parallelism,
    /// `1` = serial).
    pub fn threads(&self) -> usize {
        self.config.threads
    }

    /// True when the index z-normalizes its series and (by default)
    /// every query/window.
    pub fn znormalizes(&self) -> bool {
        self.config.znorm
    }

    /// The per-shard cluster target this index was built with (`0` = no
    /// cluster-level pruning). The actual per-shard cluster count is
    /// `min(clusters, shard size)`.
    pub fn clusters(&self) -> usize {
        self.config.clusters
    }

    /// Live-mutation generation number: `0` for a freshly built (or
    /// pre-v3-snapshot) index, incremented by every
    /// [`crate::live`] compaction.
    pub fn generation(&self) -> u64 {
        self.config.generation
    }

    /// The generation this index was compacted from (`0` when this *is*
    /// the baseline generation).
    pub fn parent(&self) -> u64 {
        self.config.parent
    }

    /// True when any shard carries a cluster-pruning layer (merged
    /// envelopes + pivot ordering) — such indexes route every scalar
    /// k-NN query through the two-level sharded kernel.
    pub fn has_clusters(&self) -> bool {
        self.shards.iter().any(|s| s.clusters().is_some())
    }

    /// Number of materialized shards (`> 1` when built with
    /// [`DtwIndexBuilder::shards`]; `0` when the index is empty or the
    /// configuration carries no flat stores — single shard with a
    /// non-store backend).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard flat envelope stores, in global candidate order
    /// (shard `s` owns candidates `shards()[s].range()`). May be empty
    /// — see [`DtwIndex::shard_count`].
    pub fn shards(&self) -> &[ShardStore] {
        &self.shards
    }

    /// Serialize this index to a self-contained, versioned, checksummed
    /// snapshot at `path`; returns the bytes written. A process holding
    /// only the snapshot can serve the index ([`DtwIndex::load`],
    /// `dtw-bounds serve --snapshot`) with **bit-identical** results —
    /// see [`snapshot`] for the format and the determinism argument.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<u64, SnapshotError> {
        snapshot::save(self, path.as_ref())
    }

    /// Load an index from a snapshot written by [`DtwIndex::save`].
    /// Rejects non-snapshot files, truncation, bit corruption and
    /// unknown versions with distinct [`SnapshotError`] variants.
    pub fn load(path: impl AsRef<Path>) -> Result<DtwIndex, SnapshotError> {
        snapshot::load(path.as_ref())
    }

    /// A cheap handle with a different screening bound (shares the
    /// prepared data — nothing is recomputed).
    pub fn with_bound(&self, bound: BoundKind) -> DtwIndex {
        let mut out = self.clone();
        out.config.bound = bound;
        out
    }

    /// A cheap handle whose new [`Searcher`]s carry a different batched
    /// prefilter backend kind (shares the prepared data).
    pub fn with_backend(&self, backend: BackendKind) -> DtwIndex {
        let mut out = self.clone();
        out.config.backend = backend;
        out
    }

    /// A cheap handle with a different search strategy.
    pub fn with_strategy(&self, strategy: SearchStrategy) -> DtwIndex {
        let mut out = self.clone();
        out.config.strategy = strategy;
        out
    }

    /// A cheap handle with a different search thread count (shares the
    /// prepared data; `0` = machine parallelism, `1` = serial).
    pub fn with_threads(&self, threads: usize) -> DtwIndex {
        let mut out = self.clone();
        out.config.threads = threads;
        out
    }

    /// A per-thread query handle. The searcher carries the scratch
    /// buffers and (for [`BackendKind::Native`]) a fresh batched
    /// prefilter; PJRT backends must be attached explicitly with
    /// [`Searcher::set_backend`] inside the owning thread.
    pub fn searcher(&self) -> Searcher {
        let backend: Option<Box<dyn LbBackend>> = match self.config.backend {
            BackendKind::Native => {
                Some(Box::new(NativeBatchLb::with_threads(self.config.threads)))
            }
            BackendKind::None => None,
            BackendKind::Pjrt => {
                // Loud on purpose: without an explicit attach this
                // searcher silently serves every batch on the scalar path.
                log::warn!(
                    "index: pjrt backends are per-thread handles and cannot be \
                     auto-constructed; attach one with Searcher::set_backend (or \
                     NnEngine::attach_batch_lb) inside the owning thread — until \
                     then batches run the scalar path"
                );
                None
            }
        };
        let l = self.train.series.first().map(|s| s.len()).unwrap_or(0);
        Searcher {
            index: self.clone(),
            scratch: Scratch::new(l),
            bound_buf: Vec::new(),
            index_buf: Vec::new(),
            order: Vec::new(),
            ranking: Ranking::default(),
            rng: Rng::seeded(self.config.seed),
            backend,
        }
    }

    /// Convenience: the `k` nearest neighbors of `query` through a
    /// one-shot [`Searcher`]. Hot paths should hold a searcher instead
    /// (amortizes scratch and backend setup).
    pub fn knn<D: Delta>(&self, query: &[f64], k: usize) -> QueryOutcome {
        self.searcher().query_values::<D>(query, &QueryOptions::k(k))
    }

    /// Convenience: answer one typed [`Query`] through a one-shot
    /// [`Searcher`].
    pub fn query<D: Delta>(&self, query: &Query) -> QueryOutcome {
        self.searcher().query::<D>(query)
    }

    /// Streaming subsequence search over this index: slide an
    /// index-length window along a sample stream and report every window
    /// (or the top-k windows) within DTW distance τ of some indexed
    /// series, screened by a cascade of lower bounds — see
    /// [`crate::stream`]. Errors when the index is empty or the options
    /// are inconsistent.
    pub fn subsequence(
        &self,
        opts: crate::stream::SubsequenceOptions,
    ) -> anyhow::Result<crate::stream::SubsequenceSearcher> {
        crate::stream::SubsequenceSearcher::new(self, opts)
    }

    /// One-shot convenience over [`DtwIndex::subsequence`]: run a whole
    /// finite sample slice through a fresh searcher and return the
    /// [`crate::stream::StreamReport`] (matches + per-stage prune stats).
    pub fn subsequence_scan<D: Delta>(
        &self,
        samples: &[f64],
        opts: crate::stream::SubsequenceOptions,
    ) -> anyhow::Result<crate::stream::StreamReport> {
        let mut searcher = self.subsequence(opts)?;
        searcher.scan::<D>(samples);
        Ok(searcher.finish())
    }
}

/// A per-thread query handle over a shared [`DtwIndex`].
///
/// Owns everything mutable about a search — scratch buffers (the hot
/// path never allocates), the candidate-order RNG, and the optional
/// batched [`LbBackend`] prefilter — so the index itself stays `Sync`.
pub struct Searcher {
    index: DtwIndex,
    scratch: Scratch,
    bound_buf: Vec<f64>,
    index_buf: Vec<usize>,
    order: Vec<usize>,
    /// Reused across batch executions (flat bound matrix + per-query
    /// candidate orders) — the batch hot path allocates nothing per call.
    ranking: Ranking,
    rng: Rng,
    backend: Option<Box<dyn LbBackend>>,
}

impl Searcher {
    /// The index this searcher reads.
    pub fn index(&self) -> &DtwIndex {
        &self.index
    }

    /// Reseed the random-order strategy's candidate shuffle (for
    /// reproducible experiments).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Rng::seeded(seed);
    }

    /// Attach (or replace) the batched screening backend.
    pub fn set_backend(&mut self, backend: Box<dyn LbBackend>) {
        log::info!("searcher: batched prefilter backend = {}", backend.name());
        self.backend = Some(backend);
    }

    /// Drop the batched backend (scalar path only).
    pub fn clear_backend(&mut self) {
        self.backend = None;
    }

    /// Detach and return the batched backend, if any (used by engines
    /// that hot-swap indexes but must keep their deployment-configured
    /// backend attachment).
    pub fn take_backend(&mut self) -> Option<Box<dyn LbBackend>> {
        self.backend.take()
    }

    /// Name of the attached screening backend, if any.
    pub fn backend_name(&self) -> Option<&'static str> {
        self.backend.as_ref().map(|b| b.name())
    }

    /// True when a batched screening backend is attached.
    pub fn has_backend(&self) -> bool {
        self.backend.is_some()
    }

    /// Answer one typed [`Query`] on the scalar path.
    pub fn query<D: Delta>(&mut self, query: &Query) -> QueryOutcome {
        self.query_values::<D>(&query.values, &query.options)
    }

    /// Answer one query given raw values and options (avoids building a
    /// [`Query`] when the caller already borrows the series).
    pub fn query_values<D: Delta>(&mut self, values: &[f64], opts: &QueryOptions) -> QueryOutcome {
        let started = Instant::now();
        let cfg = &self.index.config;
        let train = &*self.index.train;
        let params = KnnParams {
            k: opts.k.max(1),
            threshold: opts.abandon_at.unwrap_or(f64::INFINITY),
            exclude: opts.exclude,
        };
        let znorm = opts.znorm.unwrap_or(cfg.znorm);
        // A lone query cannot ride the batch prefilter: degrade to the
        // scalar sorted walk.
        let strategy = match cfg.strategy {
            SearchStrategy::SortedPrecomputed => SearchStrategy::Sorted,
            s => s,
        };
        // Sharded, clustered and/or multi-threaded candidate screening
        // (identical results at any shard/cluster/thread count — see
        // `search::knn::{knn_sharded_stores, knn_parallel}`). A sharded
        // or clustered index always fans out per shard, even on one
        // thread; brute force stays serial: it is the oracle baseline.
        let exec = Executor::new(opts.threads.unwrap_or(cfg.threads));
        let sharded = self.index.shards.len() > 1;
        let clustered = self.index.has_clusters();
        if (sharded || clustered || exec.threads() > 1)
            && strategy != SearchStrategy::BruteForce
            && !train.is_empty()
        {
            let owned = if znorm { znormalized(values) } else { values.to_vec() };
            let pq = cfg.bound.prepare_query(owned, train.w);
            let (results, stats) = if sharded || clustered {
                knn_sharded_stores::<D>(
                    &pq,
                    train,
                    &self.index.shards,
                    cfg.bound,
                    &params,
                    &exec,
                )
            } else {
                knn_parallel::<D>(&pq, train, cfg.bound, &params, &exec)
            };
            return QueryOutcome {
                neighbors: results.into_iter().map(Neighbor::from).collect(),
                stats,
                strategy,
                batched: false,
                latency: started.elapsed(),
            };
        }
        let (results, stats) = match strategy {
            SearchStrategy::BruteForce => {
                if znorm {
                    knn_brute_force::<D>(&znormalized(values), train, &params)
                } else {
                    knn_brute_force::<D>(values, train, &params)
                }
            }
            SearchStrategy::RandomOrder => {
                let owned = if znorm { znormalized(values) } else { values.to_vec() };
                let pq = cfg.bound.prepare_query(owned, train.w);
                self.order.clear();
                self.order.extend(0..train.len());
                self.rng.shuffle(&mut self.order);
                knn_random_order::<D>(
                    &pq,
                    train,
                    cfg.bound,
                    &self.order,
                    &params,
                    &mut self.scratch,
                )
            }
            SearchStrategy::Sorted | SearchStrategy::SortedPrecomputed => {
                let owned = if znorm { znormalized(values) } else { values.to_vec() };
                let pq = cfg.bound.prepare_query(owned, train.w);
                knn_sorted::<D>(
                    &pq,
                    train,
                    cfg.bound,
                    &params,
                    &mut self.scratch,
                    &mut self.bound_buf,
                    &mut self.index_buf,
                )
            }
        };
        QueryOutcome {
            neighbors: results.into_iter().map(Neighbor::from).collect(),
            stats,
            strategy,
            batched: false,
            latency: started.elapsed(),
        }
    }

    /// Answer a batch of queries sharing one [`QueryOptions`], riding the
    /// attached backend when profitable (see [`Searcher::query_batch_mixed`]).
    pub fn query_batch<D: Delta>(
        &mut self,
        queries: &[Vec<f64>],
        opts: &QueryOptions,
    ) -> Vec<QueryOutcome> {
        let refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let opt_refs: Vec<&QueryOptions> = vec![opts; queries.len()];
        self.batch_core::<D>(&refs, &opt_refs)
    }

    /// Answer a batch of `(values, options)` pairs — the router's shape,
    /// where concurrent clients may ask for different `k`.
    ///
    /// When a batched backend is attached, the strategy is sorted-family,
    /// the batch is non-trivial, every series fits the backend's shape
    /// and δ is the squared difference (the backend contract), one
    /// prefilter execution screens the whole batch and each query walks
    /// its candidates in ascending-bound order. Otherwise every query
    /// takes its scalar path. Results are exact either way.
    pub fn query_batch_mixed<D: Delta>(
        &mut self,
        items: &[(Vec<f64>, QueryOptions)],
    ) -> Vec<QueryOutcome> {
        let refs: Vec<&[f64]> = items.iter().map(|(v, _)| v.as_slice()).collect();
        let opt_refs: Vec<&QueryOptions> = items.iter().map(|(_, o)| o).collect();
        self.batch_core::<D>(&refs, &opt_refs)
    }

    /// Per-query scalar path for a whole batch. The caller already
    /// applied z-normalization to `q_views`, so it is pinned off here.
    fn scalar_fallback<D: Delta>(
        &mut self,
        q_views: &[&[f64]],
        opts: &[&QueryOptions],
    ) -> Vec<QueryOutcome> {
        q_views
            .iter()
            .zip(opts)
            .map(|(q, o)| {
                let mut o = (*o).clone();
                o.znorm = Some(false);
                self.query_values::<D>(q, &o)
            })
            .collect()
    }

    fn batch_core<D: Delta>(
        &mut self,
        queries: &[&[f64]],
        opts: &[&QueryOptions],
    ) -> Vec<QueryOutcome> {
        debug_assert_eq!(queries.len(), opts.len());
        if queries.is_empty() {
            return Vec::new();
        }
        let cfg_znorm = self.index.config.znorm;
        // Normalize up front so the backend and DTW see one view, then
        // pin znorm off for any scalar fallback below.
        let normed: Option<Vec<Vec<f64>>> =
            if queries.iter().zip(opts).any(|(_, o)| o.znorm.unwrap_or(cfg_znorm)) {
                Some(
                    queries
                        .iter()
                        .zip(opts)
                        .map(|(q, o)| {
                            if o.znorm.unwrap_or(cfg_znorm) {
                                znormalized(q)
                            } else {
                                q.to_vec()
                            }
                        })
                        .collect(),
                )
            } else {
                None
            };
        let q_views: Vec<&[f64]> = match &normed {
            Some(v) => v.iter().map(|v| v.as_slice()).collect(),
            None => queries.to_vec(),
        };

        let l = q_views[0].len();
        let sorted_family = matches!(
            self.index.config.strategy,
            SearchStrategy::Sorted | SearchStrategy::SortedPrecomputed
        );
        let use_batch = sorted_family
            && q_views.len() > 1
            && !self.index.train.is_empty()
            // The backend bound matrix is LB_KEOGH under the squared δ;
            // other deltas must stay on the scalar path to remain exact.
            && D::NAME == Squared::NAME
            // Backends require one shared length; reject up front rather
            // than paying the seed DTWs and a per-batch backend error.
            && l == self.index.train.series[0].len()
            && q_views.iter().all(|q| q.len() == l)
            && self
                .backend
                .as_ref()
                .map(|be| be.supports(q_views.len(), self.index.train.len(), l))
                .unwrap_or(false);
        if !use_batch {
            return self.scalar_fallback::<D>(&q_views, opts);
        }

        let started = Instant::now();
        let train = &*self.index.train;
        let w = train.w;
        let backend = self.backend.as_mut().expect("checked above");
        // For cutoff-honouring backends, seed each query's best-so-far
        // with its exact DTW distance to candidate 0: (partial) bounds
        // abandoned against the seed are still valid lower bounds, so
        // pruning with them at any later cutoff — including the k-th
        // best for k > 1 — stays exact; they merely sort pessimistically.
        // Branch-free backends ignore cutoffs, so skip the seed DTW and
        // start the walk cold, exactly like Algorithm 4. A query that
        // excludes candidate 0 also starts cold.
        let seeds: Vec<f64> = if backend.uses_cutoffs() {
            q_views
                .iter()
                .zip(opts)
                .map(|(q, o)| {
                    if o.exclude == Some(0) {
                        f64::INFINITY
                    } else {
                        dtw_ea::<D>(q, &train.series[0].values, w, f64::INFINITY)
                    }
                })
                .collect()
        } else {
            vec![f64::INFINITY; q_views.len()]
        };
        // A store-capable backend screens each shard's flat envelope
        // rows in place (no concatenated copy, no backend-private cache);
        // others take the PreparedSeries path. The matrix — and hence
        // the walk — is bit-identical either way.
        let shard_list = &*self.index.shards;
        let ranked = if !shard_list.is_empty() && backend.supports_stores() {
            backend.rank_sharded_into(&q_views, shard_list, &seeds, &mut self.ranking)
        } else {
            backend.rank_into(&q_views, &train.series, &seeds, &mut self.ranking)
        };
        if let Err(e) = ranked {
            log::warn!("batch prefilter failed ({e:#}); falling back to scalar");
            return self.scalar_fallback::<D>(&q_views, opts);
        }
        let ranking = &self.ranking;
        let prefilter_each = started.elapsed() / q_views.len() as u32;

        let mut out = Vec::with_capacity(q_views.len());
        for (qi, q) in q_views.iter().enumerate() {
            let q_started = Instant::now();
            let o = opts[qi];
            let params = KnnParams {
                k: o.k.max(1),
                threshold: o.abandon_at.unwrap_or(f64::INFINITY),
                exclude: o.exclude,
            };
            // A finite seed is a known candidate-0 distance; an infinite
            // one means "unseeded" (cold walk).
            let initial = if seeds[qi].is_finite() {
                Some(NnResult { nn_index: 0, distance: seeds[qi], label: train.labels[0] })
            } else {
                None
            };
            let (results, mut stats) = knn_sorted_precomputed::<D>(
                q,
                train,
                &ranking.bounds[qi],
                &ranking.order[qi],
                initial,
                &params,
                &mut self.scratch.tail,
            );
            // The seed distance was one real DTW execution for this query.
            if seeds[qi].is_finite() {
                stats.dtw_calls += 1;
            }
            out.push(QueryOutcome {
                neighbors: results.into_iter().map(Neighbor::from).collect(),
                stats,
                strategy: SearchStrategy::SortedPrecomputed,
                batched: true,
                latency: prefilter_each + q_started.elapsed(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::search::knn::{knn_brute_force, KnnParams};

    fn index_for(seed: u64) -> (crate::data::Dataset, DtwIndex) {
        let ds = generate_archive(&ArchiveSpec::new(Scale::Tiny, seed))[0].clone();
        let index = DtwIndex::builder_from_dataset(&ds).build().expect("valid dataset");
        (ds, index)
    }

    #[test]
    fn builder_validates_shapes() {
        assert!(DtwIndex::builder(vec![vec![1.0, 2.0], vec![3.0]]).build().is_err());
        assert!(DtwIndex::builder(vec![vec![]]).build().is_err());
        assert!(DtwIndex::builder(vec![vec![1.0, 2.0]]).labels(vec![0, 1]).build().is_err());
        let idx = DtwIndex::builder(vec![vec![1.0, 2.0, 3.0, 4.0]]).window(1).build().unwrap();
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.window(), 1);
        // Empty index is legal; queries return no neighbors.
        let empty = DtwIndex::builder(Vec::new()).build().unwrap();
        let out = empty.knn::<Squared>(&[1.0, 2.0], 3);
        assert!(out.neighbors.is_empty());
    }

    #[test]
    fn knn_matches_brute_force_on_every_strategy() {
        let (ds, index) = index_for(91);
        for &strategy in SearchStrategy::ALL {
            let idx = index.with_strategy(strategy);
            let mut searcher = idx.searcher();
            for q in ds.test.iter().take(4) {
                for k in [1usize, 3] {
                    let (truth, _) =
                        knn_brute_force::<Squared>(&q.values, index.train(), &KnnParams::k(k));
                    let want: Vec<f64> = truth.iter().map(|r| r.distance).collect();
                    let out =
                        searcher.query_values::<Squared>(&q.values, &QueryOptions::k(k));
                    assert_eq!(out.distances(), want, "{strategy} k={k}");
                    assert!(!out.batched);
                }
            }
        }
    }

    #[test]
    fn batched_path_matches_scalar_for_knn() {
        let (ds, index) = index_for(92);
        let idx = index
            .with_bound(BoundKind::Keogh)
            .with_strategy(SearchStrategy::SortedPrecomputed);
        let mut searcher = idx.searcher();
        assert_eq!(searcher.backend_name(), Some("native"));
        let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
        assert!(queries.len() > 1, "need a real batch");
        for k in [1usize, 3] {
            let outs = searcher.query_batch::<Squared>(&queries, &QueryOptions::k(k));
            for (out, q) in outs.iter().zip(queries.iter()) {
                assert!(out.batched, "k={k}");
                let (truth, _) =
                    knn_brute_force::<Squared>(q, index.train(), &KnnParams::k(k));
                let want: Vec<f64> = truth.iter().map(|r| r.distance).collect();
                assert_eq!(out.distances(), want, "batched k={k}");
            }
        }
    }

    #[test]
    fn lone_query_degrades_to_scalar_sorted() {
        let (ds, index) = index_for(93);
        let idx = index.with_strategy(SearchStrategy::SortedPrecomputed);
        let mut searcher = idx.searcher();
        let outs = searcher
            .query_batch::<Squared>(&[ds.test[0].values.clone()], &QueryOptions::default());
        assert_eq!(outs.len(), 1);
        assert!(!outs[0].batched);
        assert_eq!(outs[0].strategy, SearchStrategy::Sorted);
    }

    #[test]
    fn abandon_threshold_filters_neighbors() {
        let (ds, index) = index_for(94);
        let q = &ds.test[0].values;
        let full = index.knn::<Squared>(q, 5);
        assert!(!full.neighbors.is_empty());
        let tau = full.neighbors[0].distance; // strictly below the 1-NN
        let out = index
            .query::<Squared>(&Query::new(q.clone()).with_options(
                QueryOptions::k(5).with_abandon_at(tau),
            ));
        assert!(out.neighbors.is_empty(), "nothing is strictly under the 1-NN distance");
    }

    #[test]
    fn exclude_supports_self_match_removal() {
        let (_ds, index) = index_for(95);
        // Query the index with one of its own members: rank 1 is itself
        // at distance 0; excluded, the best neighbor must differ.
        let member = index.train().series[0].values.clone();
        let with_self = index.knn::<Squared>(&member, 1);
        assert_eq!(with_self.best().unwrap().distance, 0.0);
        let out = index.query::<Squared>(
            &Query::new(member).with_options(QueryOptions::k(1).with_exclude(0)),
        );
        assert_ne!(out.best().unwrap().index, 0);
    }

    #[test]
    fn znorm_policy_applies_to_train_and_query() {
        let raw = vec![
            vec![10.0, 20.0, 30.0, 20.0, 10.0, 0.0],
            vec![0.0, 1.0, 2.0, 1.0, 0.0, -1.0],
        ];
        let index = DtwIndex::builder(raw).window(1).znormalize(true).build().unwrap();
        // Same shape at a wildly different scale: under z-norm both
        // training series are identical, so the query matches at ~0.
        let out = index.knn::<Squared>(&[100.0, 200.0, 300.0, 200.0, 100.0, 0.0], 2);
        assert!(out.neighbors[0].distance < 1e-12, "{}", out.neighbors[0].distance);
        assert!(out.neighbors[1].distance < 1e-12);
        // Per-query override: raw query against normalized train differs.
        let out_raw = index.query::<Squared>(
            &Query::new(vec![100.0, 200.0, 300.0, 200.0, 100.0, 0.0])
                .with_options(QueryOptions::k(1).with_znorm(false)),
        );
        assert!(out_raw.neighbors[0].distance > 1.0);
    }

    #[test]
    fn with_bound_and_strategy_share_data() {
        let (_, index) = index_for(96);
        let other = index.with_bound(BoundKind::Keogh).with_strategy(SearchStrategy::RandomOrder);
        assert!(Arc::ptr_eq(&index.train, &other.train));
        assert!(Arc::ptr_eq(&index.shards, &other.shards));
        assert_eq!(other.bound(), BoundKind::Keogh);
        assert_eq!(other.strategy(), SearchStrategy::RandomOrder);
        assert_eq!(index.bound(), BoundKind::Webb, "original handle unchanged");
        let nb = index.with_backend(crate::runtime::BackendKind::None);
        assert_eq!(nb.backend(), crate::runtime::BackendKind::None);
        assert!(!nb.searcher().has_backend());
    }

    #[test]
    fn default_build_has_one_full_shard() {
        let (ds, index) = index_for(97);
        assert_eq!(index.shard_count(), 1, "native backend screens off the store");
        assert_eq!(index.shards()[0].range(), 0..index.len());
        let empty = DtwIndex::builder(Vec::new()).build().unwrap();
        assert_eq!(empty.shard_count(), 0);
        // Store-less configuration: single shard + non-store backend
        // skips the flat-store copy entirely.
        let storeless = DtwIndex::builder_from_dataset(&ds)
            .backend(crate::runtime::BackendKind::None)
            .build()
            .unwrap();
        assert_eq!(storeless.shard_count(), 0);
        // …but sharding always materializes, whatever the backend.
        let sharded = DtwIndex::builder_from_dataset(&ds)
            .backend(crate::runtime::BackendKind::None)
            .shards(2)
            .build()
            .unwrap();
        assert_eq!(sharded.shard_count(), 2);
    }

    #[test]
    fn sharded_index_matches_serial_results() {
        let (ds, index) = index_for(98);
        let serial = index.clone();
        for shards in [2usize, 3, 7] {
            let sharded = DtwIndex::builder_from_dataset(&ds).shards(shards).build().unwrap();
            assert_eq!(sharded.shard_count(), shards.min(sharded.len()));
            let mut s_serial = serial.searcher();
            let mut s_sharded = sharded.searcher();
            for q in ds.test.iter().take(3) {
                for k in [1usize, 3] {
                    let a = s_serial.query_values::<Squared>(&q.values, &QueryOptions::k(k));
                    let b = s_sharded.query_values::<Squared>(&q.values, &QueryOptions::k(k));
                    let pair = |o: &QueryOutcome| -> Vec<(usize, f64)> {
                        o.neighbors.iter().map(|n| (n.index, n.distance)).collect()
                    };
                    assert_eq!(pair(&a), pair(&b), "shards={shards} k={k}");
                }
            }
        }
    }

    #[test]
    fn sharded_batched_path_matches_brute_force() {
        let (ds, index) = index_for(99);
        let idx = DtwIndex::builder_from_dataset(&ds)
            .bound(BoundKind::Keogh)
            .strategy(SearchStrategy::SortedPrecomputed)
            .shards(3)
            .build()
            .unwrap();
        let mut searcher = idx.searcher();
        let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
        assert!(queries.len() > 1, "need a real batch");
        let outs = searcher.query_batch::<Squared>(&queries, &QueryOptions::k(3));
        for (out, q) in outs.iter().zip(queries.iter()) {
            assert!(out.batched);
            let (truth, _) = knn_brute_force::<Squared>(q, index.train(), &KnnParams::k(3));
            let want: Vec<f64> = truth.iter().map(|r| r.distance).collect();
            assert_eq!(out.distances(), want);
        }
    }

    #[test]
    fn snapshot_round_trip_preserves_results_and_config() {
        let (ds, _) = index_for(100);
        let index = DtwIndex::builder_from_dataset(&ds)
            .shards(3)
            .znormalize(true)
            .bound(BoundKind::Keogh)
            .build()
            .unwrap();
        let path = std::env::temp_dir()
            .join(format!("dtwb_idx_roundtrip_{}.snap", std::process::id()));
        let bytes = index.save(&path).unwrap();
        assert!(bytes > 0);
        let loaded = DtwIndex::load(&path).unwrap();
        assert_eq!(loaded.len(), index.len());
        assert_eq!(loaded.window(), index.window());
        assert_eq!(loaded.bound(), index.bound());
        assert_eq!(loaded.shard_count(), index.shard_count());
        assert!(loaded.znormalizes());
        for (a, b) in index.train().series.iter().zip(loaded.train().series.iter()) {
            assert_eq!(a.values, b.values);
            assert_eq!(a.lo, b.lo);
            assert_eq!(a.up, b.up);
            assert_eq!(a.lo_of_up, b.lo_of_up);
            assert_eq!(a.up_of_lo, b.up_of_lo);
        }
        for q in ds.test.iter().take(3) {
            let a = index.knn::<Squared>(&q.values, 3);
            let b = loaded.knn::<Squared>(&q.values, 3);
            assert_eq!(a.distances(), b.distances());
        }
        std::fs::remove_file(&path).ok();
    }
}
