//! Typed queries and outcomes for the [`super::DtwIndex`] facade.

use std::time::Duration;

use crate::search::nn::{NnResult, SearchStats};
use crate::search::SearchStrategy;

/// Per-query knobs. The default is a plain exact 1-NN query.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryOptions {
    /// Number of nearest neighbors to return (`k ≥ 1`; clamped).
    pub k: usize,
    /// Abandon threshold τ: neighbors at DTW distance ≥ τ are never
    /// reported and the searcher prunes against τ from the start — the
    /// streaming-monitor regime ("is anything within τ?"). `None`
    /// disables it.
    pub abandon_at: Option<f64>,
    /// Z-normalize the query before searching; `None` inherits the
    /// index-level policy set at build time.
    pub znorm: Option<bool>,
    /// Training index to exclude (self-match exclusion, e.g. LOOCV).
    pub exclude: Option<usize>,
    /// Worker threads for candidate screening on this query (`0` = the
    /// machine's parallelism, `1` = serial); `None` inherits the
    /// index-level [`crate::index::DtwIndexBuilder::threads`] setting.
    /// Results are identical at every thread count. Applies to the
    /// scalar search paths; a query that rides a **batched** prefilter
    /// execution is parallelized by the backend's own thread setting
    /// (the index-level knob), not this per-query override.
    pub threads: Option<usize>,
}

impl Default for QueryOptions {
    fn default() -> Self {
        QueryOptions { k: 1, abandon_at: None, znorm: None, exclude: None, threads: None }
    }
}

impl QueryOptions {
    /// Options for a plain k-NN query.
    pub fn k(k: usize) -> QueryOptions {
        QueryOptions { k, ..QueryOptions::default() }
    }

    /// Set the abandon threshold τ.
    pub fn with_abandon_at(mut self, tau: f64) -> QueryOptions {
        self.abandon_at = Some(tau);
        self
    }

    /// Override the index-level z-normalization policy for this query.
    pub fn with_znorm(mut self, znorm: bool) -> QueryOptions {
        self.znorm = Some(znorm);
        self
    }

    /// Exclude one training series (self-match exclusion).
    pub fn with_exclude(mut self, index: usize) -> QueryOptions {
        self.exclude = Some(index);
        self
    }

    /// Screen candidates on `threads` workers for this query.
    pub fn with_threads(mut self, threads: usize) -> QueryOptions {
        self.threads = Some(threads);
        self
    }
}

/// One query: the series plus its options.
#[derive(Debug, Clone)]
pub struct Query {
    /// The query series (same length as the indexed series).
    pub values: Vec<f64>,
    /// Per-query knobs.
    pub options: QueryOptions,
}

impl Query {
    /// A plain exact 1-NN query.
    pub fn new(values: Vec<f64>) -> Query {
        Query { values, options: QueryOptions::default() }
    }

    /// Ask for the `k` nearest neighbors.
    pub fn with_k(mut self, k: usize) -> Query {
        self.options.k = k;
        self
    }

    /// Replace all options.
    pub fn with_options(mut self, options: QueryOptions) -> Query {
        self.options = options;
        self
    }
}

/// One returned neighbor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Index of the training series.
    pub index: usize,
    /// Its exact DTW distance to the query.
    pub distance: f64,
    /// Its label.
    pub label: u32,
}

impl From<NnResult> for Neighbor {
    fn from(r: NnResult) -> Neighbor {
        Neighbor { index: r.nn_index, distance: r.distance, label: r.label }
    }
}

/// Everything a query returns: the neighbors (ascending by distance),
/// per-stage work counters, and which path answered.
#[derive(Debug, Clone)]
pub struct QueryOutcome {
    /// The `min(k, n)` nearest neighbors, ascending by distance (fewer
    /// when an abandon threshold filtered candidates out).
    pub neighbors: Vec<Neighbor>,
    /// Pruning counters: bound calls, candidates pruned, DTW calls and
    /// abandons — plus, for indexes built with clusters, the
    /// cluster-level counters (`cluster_lb_calls`, `clusters_pruned`,
    /// `cluster_members_pruned`): candidates a skipped cluster covers
    /// never reach the per-candidate cascade and are counted there
    /// instead of in `lb_calls`/`pruned`.
    pub stats: SearchStats,
    /// The strategy that actually ran (`SortedPrecomputed` degrades to
    /// `Sorted` for lone queries without a backend batch).
    pub strategy: SearchStrategy,
    /// True when a batched [`crate::runtime::LbBackend`] prefilter
    /// screened this query.
    pub batched: bool,
    /// Search latency (batch prefilter cost amortized per query).
    pub latency: Duration,
}

impl QueryOutcome {
    /// The nearest neighbor, if any candidate survived.
    pub fn best(&self) -> Option<&Neighbor> {
        self.neighbors.first()
    }

    /// The nearest neighbor as a legacy [`NnResult`] (the "no neighbor"
    /// sentinel when the index is empty or τ filtered everything).
    pub fn best_nn(&self) -> NnResult {
        self.best()
            .map(|n| NnResult { nn_index: n.index, distance: n.distance, label: n.label })
            .unwrap_or_else(NnResult::none)
    }

    /// The neighbor distances, ascending.
    pub fn distances(&self) -> Vec<f64> {
        self.neighbors.iter().map(|n| n.distance).collect()
    }
}
