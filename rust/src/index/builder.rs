//! The [`DtwIndexBuilder`]: every knob of the facade in one place, with
//! validation at `build()` so a constructed [`DtwIndex`] is always
//! internally consistent.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::bounds::envelope::merge_envelopes_into;
use crate::bounds::store::{EnvelopeStore, ShardClusters, ShardStore};
use crate::bounds::{keogh, BoundKind, PreparedSeries};
use crate::data::rng::Rng;
use crate::data::znorm::znormalize;
use crate::data::Dataset;
use crate::delta::Squared;
use crate::dtw::dtw_ea_pruned;
use crate::exec::Executor;
use crate::runtime::BackendKind;
use crate::search::{PreparedTrainSet, SearchStrategy};

use super::{DtwIndex, IndexConfig};

/// Builder for [`DtwIndex`] — see the crate-level quickstart.
///
/// Defaults: window `max(1, ℓ/10)`, `LB_Webb`, [`SearchStrategy::Sorted`],
/// [`BackendKind::Native`] batched prefilter, no z-normalization,
/// `max_batch = 16`, single-threaded search, one shard.
#[derive(Debug, Clone)]
pub struct DtwIndexBuilder {
    series: Vec<Vec<f64>>,
    labels: Option<Vec<u32>>,
    window: Option<usize>,
    bound: BoundKind,
    strategy: SearchStrategy,
    backend: BackendKind,
    max_batch: usize,
    znorm: bool,
    seed: u64,
    threads: usize,
    shards: usize,
    clusters: usize,
    clusters_auto: bool,
}

impl DtwIndexBuilder {
    pub(super) fn new(series: Vec<Vec<f64>>) -> DtwIndexBuilder {
        DtwIndexBuilder {
            series,
            labels: None,
            window: None,
            bound: BoundKind::Webb,
            strategy: SearchStrategy::Sorted,
            backend: BackendKind::Native,
            max_batch: 16,
            znorm: false,
            seed: 0x5EED,
            threads: 1,
            shards: 1,
            clusters: 0,
            clusters_auto: false,
        }
    }

    pub(super) fn from_dataset(ds: &Dataset) -> DtwIndexBuilder {
        let mut b =
            DtwIndexBuilder::new(ds.train.iter().map(|s| s.values.clone()).collect());
        b.labels = Some(ds.train.iter().map(|s| s.label).collect());
        b.window = Some(ds.window.max(1));
        b
    }

    /// Per-series labels (defaults to all-zero when the corpus is
    /// unlabeled). Length must match the series count.
    pub fn labels(mut self, labels: Vec<u32>) -> DtwIndexBuilder {
        self.labels = Some(labels);
        self
    }

    /// Warping window `w` (Sakoe–Chiba band radius).
    pub fn window(mut self, w: usize) -> DtwIndexBuilder {
        self.window = Some(w);
        self
    }

    /// Lower bound used for screening (default `LB_Webb`).
    pub fn bound(mut self, bound: BoundKind) -> DtwIndexBuilder {
        self.bound = bound;
        self
    }

    /// Search strategy (default [`SearchStrategy::Sorted`]).
    pub fn strategy(mut self, strategy: SearchStrategy) -> DtwIndexBuilder {
        self.strategy = strategy;
        self
    }

    /// Which batched prefilter backend new [`super::Searcher`]s carry
    /// (default [`BackendKind::Native`]). [`BackendKind::Pjrt`] handles
    /// are not constructible here — attach one per searcher with
    /// [`super::Searcher::set_backend`].
    pub fn backend(mut self, backend: BackendKind) -> DtwIndexBuilder {
        self.backend = backend;
        self
    }

    /// Cap on how many queued queries ride one prefilter execution.
    pub fn max_batch(mut self, max_batch: usize) -> DtwIndexBuilder {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Z-normalize the indexed series now and every query at search time
    /// (the UCR evaluation convention). Off by default.
    pub fn znormalize(mut self, znorm: bool) -> DtwIndexBuilder {
        self.znorm = znorm;
        self
    }

    /// Seed for the random-order strategy's per-query candidate shuffle.
    pub fn seed(mut self, seed: u64) -> DtwIndexBuilder {
        self.seed = seed;
        self
    }

    /// Worker threads for search (default 1 = serial; `0` = the
    /// machine's available parallelism). With `threads > 1` a searcher
    /// screens candidates in parallel with a shared best-so-far cutoff
    /// and the batched prefilter scores query rows in parallel — the
    /// returned neighbors are **identical at every thread count** (only
    /// the work counters are scheduling-dependent). Per-query override:
    /// [`super::QueryOptions::with_threads`].
    pub fn threads(mut self, threads: usize) -> DtwIndexBuilder {
        self.threads = threads;
        self
    }

    /// Partition the candidates into `shards` contiguous shards, each
    /// owning its own flat
    /// [`EnvelopeStore`](crate::bounds::store::EnvelopeStore) (clamped
    /// to `1..=n` at build time; sizes differ by at most one). A sharded
    /// index fans every k-NN / 1-NN / stream search out **per shard**
    /// on the executor with a shared best-so-far cutoff, store-capable
    /// batched backends screen each shard's flat rows in place, and
    /// snapshots persist the shards verbatim — the returned neighbors
    /// and stream matches are **identical at every shard count**
    /// (`rust/tests/persist.rs` pins sharded ≡ serial bit-exactly).
    pub fn shards(mut self, shards: usize) -> DtwIndexBuilder {
        self.shards = shards.max(1);
        self
    }

    /// Group each shard's candidates into up to `clusters` pivot-led
    /// clusters with precomputed **merged envelopes**, enabling
    /// cluster-level pruning on every search path (`0` = off, the
    /// default). Clustering is deterministic in the builder's
    /// [`DtwIndexBuilder::seed`]: pivots are seeded farthest-first on an
    /// `LB_KEOGH` proxy distance (a valid DTW lower bound, so "far under
    /// the proxy" implies "far under DTW"), members join their nearest
    /// pivot, and each cluster's members are ordered nearest-pivot-first
    /// by a fixed-cutoff exact DTW to the pivot — all ties break on the
    /// lower index. Results are **bit-identical at every cluster count**
    /// (the cluster layer only ever skips candidates whose merged-
    /// envelope bound proves them outside the cutoff); only the work
    /// counters change. Setting `clusters > 0` materializes shard stores
    /// even for configurations that would otherwise skip them.
    pub fn clusters(mut self, clusters: usize) -> DtwIndexBuilder {
        self.clusters = clusters;
        self.clusters_auto = false;
        self
    }

    /// Pick the cluster count automatically: ≈√(shard size) clusters per
    /// shard, the classic balance point between the per-cluster bound
    /// overhead (k extra bounds per query) and the per-member savings.
    pub fn clusters_auto(mut self) -> DtwIndexBuilder {
        self.clusters_auto = true;
        self
    }

    /// Validate and build: prepares every series' envelopes once (the
    /// paper's off-query-path preparation step).
    ///
    /// Errors when series lengths differ (bounds assume one shared
    /// length), series are empty, or labels mismatch the series count.
    pub fn build(self) -> Result<DtwIndex> {
        let n = self.series.len();
        let l = self.series.first().map(|s| s.len()).unwrap_or(0);
        if let Some(bad) = self.series.iter().position(|s| s.len() != l) {
            bail!("series {bad} has length {}, expected {l} (bounds assume one shared length)",
                self.series[bad].len());
        }
        if n > 0 && l == 0 {
            bail!("cannot index empty series");
        }
        let labels = match self.labels {
            Some(labels) => {
                if labels.len() != n {
                    bail!("{} labels for {n} series", labels.len());
                }
                labels
            }
            None => vec![0; n],
        };
        let w = self.window.unwrap_or_else(|| (l / 10).max(1));
        // Envelope preparation is embarrassingly parallel over series —
        // with a threads knob set, the build itself uses it too.
        let exec = crate::exec::Executor::new(self.threads);
        let series: Vec<PreparedSeries> = if exec.threads() > 1 && n > 1 {
            // Ownership of each series moves into its worker (mem::take
            // through the per-slot lock) — no second copy of the
            // training data, unlike a clone-per-series scheme.
            let inputs: Vec<std::sync::Mutex<Vec<f64>>> = self
                .series
                .into_iter()
                .map(|mut values| {
                    if self.znorm {
                        znormalize(&mut values);
                    }
                    std::sync::Mutex::new(values)
                })
                .collect();
            let slots: Vec<std::sync::Mutex<Option<PreparedSeries>>> =
                (0..n).map(|_| std::sync::Mutex::new(None)).collect();
            exec.run(n, 4, |_wid, queue| {
                while let Some(range) = queue.next_chunk() {
                    for i in range {
                        let values = std::mem::take(&mut *inputs[i].lock().unwrap());
                        *slots[i].lock().unwrap() = Some(PreparedSeries::prepare(values, w));
                    }
                }
            });
            slots
                .into_iter()
                .map(|slot| slot.into_inner().unwrap().expect("every slot filled"))
                .collect()
        } else {
            self.series
                .into_iter()
                .map(|mut values| {
                    if self.znorm {
                        znormalize(&mut values);
                    }
                    PreparedSeries::prepare(values, w)
                })
                .collect()
        };
        // Resolve the auto knob to a concrete per-shard target so the
        // config (and snapshots) always carry a plain number: ≈√(shard
        // size), computed from the same deterministic partition
        // arithmetic `partition_shards` uses.
        let clusters = if self.clusters_auto {
            let shards_eff = self.shards.clamp(1, n.max(1));
            let shard_len = n.div_ceil(shards_eff);
            (shard_len as f64).sqrt().ceil() as usize
        } else {
            self.clusters
        };
        // Candidate ownership: cut the prepared set into contiguous
        // per-shard flat stores — the unit of search fan-out, batched
        // screening, and snapshot persistence. Built when sharding is
        // requested, the configured backend screens straight off flat
        // stores (Native), or cluster pruning is on (clusters live
        // inside shard stores); store-less configurations (single shard
        // + scalar/PJRT screening, no clusters) skip the copy entirely —
        // `save()` materializes a transient single-shard partition
        // instead.
        let mut shards =
            if self.shards > 1 || self.backend == BackendKind::Native || clusters > 0 {
                crate::bounds::store::partition_shards(&series, self.shards)
            } else {
                Vec::new()
            };
        if clusters > 0 {
            let mut rng = Rng::seeded(self.seed);
            shards = shards
                .into_iter()
                .map(|s| {
                    let mut shard_rng = rng.fork(s.start() as u64);
                    let cl = build_shard_clusters(
                        &series[s.range()],
                        s.store(),
                        w,
                        clusters,
                        &mut shard_rng,
                        &exec,
                    );
                    s.with_clusters(cl)
                })
                .collect();
        }
        Ok(DtwIndex {
            train: Arc::new(PreparedTrainSet { labels, series, w }),
            shards: Arc::new(shards),
            config: IndexConfig {
                bound: self.bound,
                strategy: self.strategy,
                backend: self.backend,
                max_batch: self.max_batch,
                znorm: self.znorm,
                seed: self.seed,
                threads: self.threads,
                clusters,
                generation: 0,
                parent: 0,
            },
        })
    }
}

/// Raw base pointer for disjoint per-index writes from the exec pool
/// (each index is claimed by exactly one worker via the work queue).
struct SlotsPtr(*mut f64);
unsafe impl Send for SlotsPtr {}
unsafe impl Sync for SlotsPtr {}

/// Series per work-queue chunk in the parallel clustering passes.
const CLUSTER_CHUNK: usize = 16;

/// Cluster one shard's candidates around pivots — deterministic in
/// `rng` (forked per shard from the builder seed) and in the member
/// order, independent of thread count.
///
/// 1. **Seeding** (k-medoids-style farthest-first): the first pivot is
///    drawn uniformly; each further pivot is the unchosen member whose
///    proxy distance to its nearest pivot is largest (ties → lowest
///    offset). The proxy is `LB_KEOGH(member, pivot envelope)` — `O(ℓ)`
///    per pair off the shard's flat store, and a valid DTW lower bound,
///    so "far under the proxy" implies "far under DTW".
/// 2. **Assignment**: every member joins its nearest pivot under the
///    proxy (strict improvement only, so ties keep the earliest pivot;
///    pivots own themselves). Proxy rows are computed in parallel on
///    the exec pool; the min/argmin fold is serial, so the assignment
///    is identical at every thread count.
/// 3. **Member order**: within each cluster, members sort ascending by
///    `(pivot DTW distance, offset)` where the distance is exact DTW
///    under a fixed, query-independent cutoff (4× the largest
///    assignment proxy; abandoned distances record as `INFINITY` and
///    sort last). Near-pivot members screen first at query time, which
///    tightens the shared cutoff fastest. The distances are advisory
///    ordering only — DTW violates the triangle inequality, so no
///    skip test is ever derived from them.
/// 4. **Merged envelopes**: elementwise min-lo/max-up over each
///    cluster's members ([`merge_envelopes_into`]), packed as one flat
///    [`EnvelopeStore`] row per cluster.
fn build_shard_clusters(
    series: &[PreparedSeries],
    store: &EnvelopeStore,
    w: usize,
    target: usize,
    rng: &mut Rng,
    exec: &Executor,
) -> ShardClusters {
    let len = series.len();
    let l = series.first().map(|s| s.len()).unwrap_or(0);
    let k = target.clamp(1, len);

    // Farthest-first seeding + nearest-pivot assignment on the proxy.
    let mut min_dist = vec![f64::INFINITY; len];
    let mut assign = vec![0u32; len];
    let mut chosen = vec![false; len];
    let mut pivots: Vec<u32> = Vec::with_capacity(k);
    let mut proxy = vec![0.0f64; len];
    for c in 0..k {
        let p = if c == 0 {
            rng.below(len)
        } else {
            let mut best = usize::MAX;
            let mut best_d = f64::NEG_INFINITY;
            for (i, &d) in min_dist.iter().enumerate() {
                if !chosen[i] && d > best_d {
                    best = i;
                    best_d = d;
                }
            }
            best
        };
        chosen[p] = true;
        pivots.push(p as u32);
        assign[p] = c as u32;
        min_dist[p] = 0.0;
        let (p_lo, p_up) = (store.lo_row(p), store.up_row(p));
        let slots = SlotsPtr(proxy.as_mut_ptr());
        let slots = &slots;
        exec.run(len, CLUSTER_CHUNK, move |_wid, queue| {
            while let Some(range) = queue.next_chunk() {
                for i in range {
                    let d =
                        keogh::lb_keogh_flat::<Squared>(&series[i].values, p_lo, p_up, f64::INFINITY);
                    // Safety: i is claimed by this worker alone, and the
                    // slot buffer was sized to `len` above.
                    unsafe { *slots.0.add(i) = d };
                }
            }
        });
        for i in 0..len {
            if proxy[i] < min_dist[i] {
                min_dist[i] = proxy[i];
                assign[i] = c as u32;
            }
        }
    }

    // Exact pivot DTW under a fixed, query-independent cutoff. Abandoned
    // distances (INFINITY) only demote a member to the back of its
    // cluster's visit order — they carry no pruning weight.
    let max_proxy = min_dist.iter().cloned().fold(0.0f64, f64::max);
    let fixed_cutoff = 4.0 * max_proxy;
    let mut pivot_dist = vec![0.0f64; len];
    {
        let assign = &assign;
        let pivots = &pivots;
        let slots = SlotsPtr(pivot_dist.as_mut_ptr());
        let slots = &slots;
        exec.run(len, CLUSTER_CHUNK, move |_wid, queue| {
            while let Some(range) = queue.next_chunk() {
                for i in range {
                    let p = pivots[assign[i] as usize] as usize;
                    let d = if i == p {
                        0.0
                    } else {
                        dtw_ea_pruned::<Squared>(
                            &series[i].values,
                            &series[p].values,
                            w,
                            fixed_cutoff,
                            None,
                        )
                    };
                    // Safety: disjoint slots, as above.
                    unsafe { *slots.0.add(i) = d };
                }
            }
        });
    }

    // Group members by cluster, near-pivot-first, and fold the merged
    // envelopes.
    let mut groups: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (i, &c) in assign.iter().enumerate() {
        groups[c as usize].push(i as u32);
    }
    let mut members: Vec<u32> = Vec::with_capacity(len);
    let mut offsets: Vec<u32> = Vec::with_capacity(k + 1);
    offsets.push(0);
    let mut lo_rows: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut up_rows: Vec<Vec<f64>> = Vec::with_capacity(k);
    for group in &mut groups {
        group.sort_unstable_by(|&a, &b| {
            pivot_dist[a as usize]
                .partial_cmp(&pivot_dist[b as usize])
                .expect("distances are never NaN")
                .then(a.cmp(&b))
        });
        let mut lo = vec![f64::INFINITY; l];
        let mut up = vec![f64::NEG_INFINITY; l];
        for &m in group.iter() {
            let t = &series[m as usize];
            merge_envelopes_into(&mut lo, &mut up, &t.lo, &t.up);
        }
        members.extend_from_slice(group);
        offsets.push(members.len() as u32);
        lo_rows.push(lo);
        up_rows.push(up);
    }
    let env = EnvelopeStore::from_rows(&lo_rows, &up_rows);
    ShardClusters::from_parts(len, members, offsets, pivots, pivot_dist, env)
        .expect("builder-produced clusters satisfy every invariant")
}
