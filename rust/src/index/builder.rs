//! The [`DtwIndexBuilder`]: every knob of the facade in one place, with
//! validation at `build()` so a constructed [`DtwIndex`] is always
//! internally consistent.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::bounds::{BoundKind, PreparedSeries};
use crate::data::znorm::znormalize;
use crate::data::Dataset;
use crate::runtime::BackendKind;
use crate::search::{PreparedTrainSet, SearchStrategy};

use super::{DtwIndex, IndexConfig};

/// Builder for [`DtwIndex`] — see the crate-level quickstart.
///
/// Defaults: window `max(1, ℓ/10)`, `LB_Webb`, [`SearchStrategy::Sorted`],
/// [`BackendKind::Native`] batched prefilter, no z-normalization,
/// `max_batch = 16`, single-threaded search, one shard.
#[derive(Debug, Clone)]
pub struct DtwIndexBuilder {
    series: Vec<Vec<f64>>,
    labels: Option<Vec<u32>>,
    window: Option<usize>,
    bound: BoundKind,
    strategy: SearchStrategy,
    backend: BackendKind,
    max_batch: usize,
    znorm: bool,
    seed: u64,
    threads: usize,
    shards: usize,
}

impl DtwIndexBuilder {
    pub(super) fn new(series: Vec<Vec<f64>>) -> DtwIndexBuilder {
        DtwIndexBuilder {
            series,
            labels: None,
            window: None,
            bound: BoundKind::Webb,
            strategy: SearchStrategy::Sorted,
            backend: BackendKind::Native,
            max_batch: 16,
            znorm: false,
            seed: 0x5EED,
            threads: 1,
            shards: 1,
        }
    }

    pub(super) fn from_dataset(ds: &Dataset) -> DtwIndexBuilder {
        let mut b =
            DtwIndexBuilder::new(ds.train.iter().map(|s| s.values.clone()).collect());
        b.labels = Some(ds.train.iter().map(|s| s.label).collect());
        b.window = Some(ds.window.max(1));
        b
    }

    /// Per-series labels (defaults to all-zero when the corpus is
    /// unlabeled). Length must match the series count.
    pub fn labels(mut self, labels: Vec<u32>) -> DtwIndexBuilder {
        self.labels = Some(labels);
        self
    }

    /// Warping window `w` (Sakoe–Chiba band radius).
    pub fn window(mut self, w: usize) -> DtwIndexBuilder {
        self.window = Some(w);
        self
    }

    /// Lower bound used for screening (default `LB_Webb`).
    pub fn bound(mut self, bound: BoundKind) -> DtwIndexBuilder {
        self.bound = bound;
        self
    }

    /// Search strategy (default [`SearchStrategy::Sorted`]).
    pub fn strategy(mut self, strategy: SearchStrategy) -> DtwIndexBuilder {
        self.strategy = strategy;
        self
    }

    /// Which batched prefilter backend new [`super::Searcher`]s carry
    /// (default [`BackendKind::Native`]). [`BackendKind::Pjrt`] handles
    /// are not constructible here — attach one per searcher with
    /// [`super::Searcher::set_backend`].
    pub fn backend(mut self, backend: BackendKind) -> DtwIndexBuilder {
        self.backend = backend;
        self
    }

    /// Cap on how many queued queries ride one prefilter execution.
    pub fn max_batch(mut self, max_batch: usize) -> DtwIndexBuilder {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Z-normalize the indexed series now and every query at search time
    /// (the UCR evaluation convention). Off by default.
    pub fn znormalize(mut self, znorm: bool) -> DtwIndexBuilder {
        self.znorm = znorm;
        self
    }

    /// Seed for the random-order strategy's per-query candidate shuffle.
    pub fn seed(mut self, seed: u64) -> DtwIndexBuilder {
        self.seed = seed;
        self
    }

    /// Worker threads for search (default 1 = serial; `0` = the
    /// machine's available parallelism). With `threads > 1` a searcher
    /// screens candidates in parallel with a shared best-so-far cutoff
    /// and the batched prefilter scores query rows in parallel — the
    /// returned neighbors are **identical at every thread count** (only
    /// the work counters are scheduling-dependent). Per-query override:
    /// [`super::QueryOptions::with_threads`].
    pub fn threads(mut self, threads: usize) -> DtwIndexBuilder {
        self.threads = threads;
        self
    }

    /// Partition the candidates into `shards` contiguous shards, each
    /// owning its own flat
    /// [`EnvelopeStore`](crate::bounds::store::EnvelopeStore) (clamped
    /// to `1..=n` at build time; sizes differ by at most one). A sharded
    /// index fans every k-NN / 1-NN / stream search out **per shard**
    /// on the executor with a shared best-so-far cutoff, store-capable
    /// batched backends screen each shard's flat rows in place, and
    /// snapshots persist the shards verbatim — the returned neighbors
    /// and stream matches are **identical at every shard count**
    /// (`rust/tests/persist.rs` pins sharded ≡ serial bit-exactly).
    pub fn shards(mut self, shards: usize) -> DtwIndexBuilder {
        self.shards = shards.max(1);
        self
    }

    /// Validate and build: prepares every series' envelopes once (the
    /// paper's off-query-path preparation step).
    ///
    /// Errors when series lengths differ (bounds assume one shared
    /// length), series are empty, or labels mismatch the series count.
    pub fn build(self) -> Result<DtwIndex> {
        let n = self.series.len();
        let l = self.series.first().map(|s| s.len()).unwrap_or(0);
        if let Some(bad) = self.series.iter().position(|s| s.len() != l) {
            bail!("series {bad} has length {}, expected {l} (bounds assume one shared length)",
                self.series[bad].len());
        }
        if n > 0 && l == 0 {
            bail!("cannot index empty series");
        }
        let labels = match self.labels {
            Some(labels) => {
                if labels.len() != n {
                    bail!("{} labels for {n} series", labels.len());
                }
                labels
            }
            None => vec![0; n],
        };
        let w = self.window.unwrap_or_else(|| (l / 10).max(1));
        // Envelope preparation is embarrassingly parallel over series —
        // with a threads knob set, the build itself uses it too.
        let exec = crate::exec::Executor::new(self.threads);
        let series: Vec<PreparedSeries> = if exec.threads() > 1 && n > 1 {
            // Ownership of each series moves into its worker (mem::take
            // through the per-slot lock) — no second copy of the
            // training data, unlike a clone-per-series scheme.
            let inputs: Vec<std::sync::Mutex<Vec<f64>>> = self
                .series
                .into_iter()
                .map(|mut values| {
                    if self.znorm {
                        znormalize(&mut values);
                    }
                    std::sync::Mutex::new(values)
                })
                .collect();
            let slots: Vec<std::sync::Mutex<Option<PreparedSeries>>> =
                (0..n).map(|_| std::sync::Mutex::new(None)).collect();
            exec.run(n, 4, |_wid, queue| {
                while let Some(range) = queue.next_chunk() {
                    for i in range {
                        let values = std::mem::take(&mut *inputs[i].lock().unwrap());
                        *slots[i].lock().unwrap() = Some(PreparedSeries::prepare(values, w));
                    }
                }
            });
            slots
                .into_iter()
                .map(|slot| slot.into_inner().unwrap().expect("every slot filled"))
                .collect()
        } else {
            self.series
                .into_iter()
                .map(|mut values| {
                    if self.znorm {
                        znormalize(&mut values);
                    }
                    PreparedSeries::prepare(values, w)
                })
                .collect()
        };
        // Candidate ownership: cut the prepared set into contiguous
        // per-shard flat stores — the unit of search fan-out, batched
        // screening, and snapshot persistence. Built when sharding is
        // requested or the configured backend screens straight off flat
        // stores (Native); store-less configurations (single shard +
        // scalar/PJRT screening) skip the copy entirely — `save()`
        // materializes a transient single-shard partition instead.
        let shards = if self.shards > 1 || self.backend == BackendKind::Native {
            crate::bounds::store::partition_shards(&series, self.shards)
        } else {
            Vec::new()
        };
        Ok(DtwIndex {
            train: Arc::new(PreparedTrainSet { labels, series, w }),
            shards: Arc::new(shards),
            config: IndexConfig {
                bound: self.bound,
                strategy: self.strategy,
                backend: self.backend,
                max_batch: self.max_batch,
                znorm: self.znorm,
                seed: self.seed,
                threads: self.threads,
            },
        })
    }
}
