//! The versioned, checksummed [`DtwIndex`] snapshot format — cold-start
//! persistence for the sharded index, pure `std` (no serde).
//!
//! ## Why a custom format
//!
//! The win at scale (Keogh-style exact indexing, the UCR-suite
//! discipline) comes from preparing bound metadata **once** and serving
//! forever after from the prepared form. A snapshot therefore stores
//! exactly what a serving process needs: the training series, labels,
//! the z-norm policy and window/bound configuration, and — verbatim —
//! every shard's flat 64-byte-aligned
//! [`EnvelopeStore`](crate::bounds::store::EnvelopeStore) payload.
//! Loading a shard is a length check plus one bulk copy back into a
//! fresh aligned allocation; the only recomputation on the cold-start
//! path is the `O(n·ℓ)` envelope-of-envelope pass, which is a
//! deterministic pure function of the stored envelopes — so a loaded
//! index produces **bit-identical** search results to the index that
//! was saved, by construction (pinned by `rust/tests/persist.rs`).
//!
//! ## Layout (version 3, all integers/floats little-endian)
//!
//! ```text
//! offset size  field
//!      0    8  magic  "DTWBSNAP"
//!      8    4  format version (u32) = 3
//!     12    8  FNV-1a-64 checksum of the body (u64)
//!     20    8  body length in bytes (u64)
//!     28    …  body:
//!              flags(u32: bit0 = znorm)
//!              bound tag(u32) · bound k(u32) · strategy(u32) · backend(u32)
//!              max_batch(u64) · seed(u64) · threads(u64)
//!              clusters(u64)                                  [v2+]
//!              generation(u64) · parent generation(u64)       [v3+]
//!              shard count(u64) · n(u64) · ℓ(u64) · w(u64) · stride(u64)
//!              labels: n × u32
//!              values: n·ℓ × f64 (raw bits — exact round-trip)
//!              per shard: size(u64), then 2·size·stride × f64
//!                         (the shard's padded SoA payload: lo rows, up rows)
//!                then     cluster count k(u64)                [v2+]
//!                         and, when k > 0:
//!                           offsets: (k+1) × u32
//!                           members: size × u32
//!                           pivots: k × u32
//!                           pivot distances: size × f64 (raw bits)
//!                           merged envelopes: 2·k·stride × f64
//! ```
//!
//! **Version 1** files (everything marked `[v2+]` absent) still load:
//! they deserialize as clusterless indexes (`clusters = 0`, no cluster
//! sections), bit-identical to how the v1 reader loaded them.
//! **Version 2** files (the `[v3+]` generation pair absent) load as
//! generation 0 with parent 0 — the pre-live-mutation baseline. The
//! writer always emits the current version.
//!
//! Truncation, bit corruption and future versions are three *distinct*
//! failures ([`SnapshotError::Truncated`],
//! [`SnapshotError::ChecksumMismatch`],
//! [`SnapshotError::UnsupportedVersion`]): the body length is checked
//! before the checksum, and the checksum before any field is trusted.

use std::fmt;
use std::path::Path;
use std::sync::Arc;

use crate::bounds::envelope;
use crate::bounds::store::{EnvelopeStore, ShardClusters, ShardStore};
use crate::bounds::{BoundKind, PreparedSeries};
use crate::runtime::BackendKind;
use crate::search::{PreparedTrainSet, SearchStrategy};

use super::{DtwIndex, IndexConfig};

/// File magic: identifies a dtw-bounds index snapshot.
pub const MAGIC: [u8; 8] = *b"DTWBSNAP";
/// Current format version (the writer always emits this; the reader
/// accepts every version from 1 up to it).
pub const VERSION: u32 = 3;

/// Everything that can go wrong reading or writing a snapshot. Each
/// failure mode is a distinct variant so callers (CLI exit paths, the
/// server's `err=` replies) can report *what* is wrong with the file,
/// not just that something is.
#[derive(Debug)]
pub enum SnapshotError {
    /// The underlying read/write failed (missing path, permissions, …).
    Io(std::io::Error),
    /// The file does not start with the snapshot magic — not a snapshot.
    BadMagic,
    /// The file is a snapshot from a newer (or unknown) format version.
    UnsupportedVersion {
        /// Version found in the file.
        found: u32,
        /// Highest version this build reads.
        supported: u32,
    },
    /// The file is shorter than its header says it should be.
    Truncated {
        /// Which field/section ran out of bytes.
        context: &'static str,
    },
    /// The body bytes do not hash to the stored checksum (bit rot,
    /// partial overwrite, manual edits).
    ChecksumMismatch {
        /// Checksum recorded in the header.
        stored: u64,
        /// Checksum of the bytes actually present.
        computed: u64,
    },
    /// The bytes are intact but the fields are inconsistent (impossible
    /// shapes, unknown enum tags, trailing garbage).
    Corrupt(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "io: {e}"),
            SnapshotError::BadMagic => {
                write!(f, "bad magic (not a dtw-bounds index snapshot)")
            }
            SnapshotError::UnsupportedVersion { found, supported } => {
                write!(f, "unsupported snapshot version {found} (this build reads <= {supported})")
            }
            SnapshotError::Truncated { context } => {
                write!(f, "truncated snapshot (ran out of bytes reading {context})")
            }
            SnapshotError::ChecksumMismatch { stored, computed } => {
                write!(
                    f,
                    "checksum mismatch (header says {stored:#018x}, body hashes to \
                     {computed:#018x})"
                )
            }
            SnapshotError::Corrupt(why) => write!(f, "corrupt snapshot: {why}"),
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SnapshotError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> SnapshotError {
        SnapshotError::Io(e)
    }
}

/// The header of a snapshot, as `dtw-bounds index inspect` reports it —
/// everything except the bulk payload.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotInfo {
    /// Format version.
    pub version: u32,
    /// Body checksum (FNV-1a 64).
    pub checksum: u64,
    /// Whole file size in bytes.
    pub bytes: u64,
    /// Indexed series count.
    pub series: usize,
    /// Series length ℓ.
    pub series_len: usize,
    /// Warping window.
    pub window: usize,
    /// Shard count.
    pub shards: usize,
    /// Screening bound.
    pub bound: BoundKind,
    /// Search strategy.
    pub strategy: SearchStrategy,
    /// Backend kind new searchers instantiate.
    pub backend: BackendKind,
    /// Whether the index z-normalizes (series are stored normalized).
    pub znorm: bool,
    /// Batched-prefilter batch cap.
    pub max_batch: usize,
    /// Configured search thread count.
    pub threads: usize,
    /// Random-order strategy seed.
    pub seed: u64,
    /// Per-shard cluster target (`0` = no cluster pruning; always `0`
    /// for version-1 files).
    pub clusters: usize,
    /// Live-mutation generation number (always `0` for pre-v3 files:
    /// the frozen, never-compacted baseline).
    pub generation: u64,
    /// Generation this snapshot was compacted from (`0` when it *is*
    /// the baseline, and always `0` for pre-v3 files).
    pub parent: u64,
}

// ---------------------------------------------------------------------
// Checksum + little-endian plumbing
// ---------------------------------------------------------------------

/// FNV-1a 64 over `bytes` — dependency-free, stable across platforms.
/// Shared with the live write-ahead log ([`crate::live::wal`]), whose
/// per-record checksums use the same function.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(PRIME);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(out: &mut Vec<u8>, values: &[f64]) {
    out.reserve(values.len() * 8);
    for &v in values {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Bounds-checked little-endian reader with typed truncation errors.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, context: &'static str) -> Result<&'a [u8], SnapshotError> {
        if self.buf.len() - self.pos < n {
            return Err(SnapshotError::Truncated { context });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self, context: &'static str) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4, context)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self, context: &'static str) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8, context)?.try_into().expect("8 bytes")))
    }

    /// A u64 field that must fit a `usize` (impossible shapes become
    /// typed corruption instead of a platform-dependent panic).
    fn size(&mut self, context: &'static str) -> Result<usize, SnapshotError> {
        usize::try_from(self.u64(context)?)
            .map_err(|_| SnapshotError::Corrupt(format!("{context} overflows usize")))
    }

    fn u32s(&mut self, n: usize, context: &'static str) -> Result<Vec<u32>, SnapshotError> {
        let len = n
            .checked_mul(4)
            .ok_or_else(|| SnapshotError::Corrupt(format!("{context} length overflows")))?;
        let bytes = self.take(len, context)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }

    fn f64s(&mut self, n: usize, context: &'static str) -> Result<Vec<f64>, SnapshotError> {
        let len = n
            .checked_mul(8)
            .ok_or_else(|| SnapshotError::Corrupt(format!("{context} length overflows")))?;
        let bytes = self.take(len, context)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().expect("8 bytes"))))
            .collect())
    }

    /// Bytes left unread — checked before any header-count-sized
    /// allocation, so a checksum-valid file lying about its counts
    /// fails typed instead of panicking/aborting on a huge reserve.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn exhausted(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ---------------------------------------------------------------------
// Enum tags (append-only: new variants get new tags, old tags stay)
// ---------------------------------------------------------------------

fn encode_bound(bound: BoundKind) -> (u32, u32) {
    match bound {
        BoundKind::KimFL => (0, 0),
        BoundKind::Keogh => (1, 0),
        BoundKind::Improved => (2, 0),
        BoundKind::Enhanced(k) => (3, k as u32),
        BoundKind::Petitjean => (4, 0),
        BoundKind::PetitjeanNoLr => (5, 0),
        BoundKind::Webb => (6, 0),
        BoundKind::WebbNoLr => (7, 0),
        BoundKind::WebbStar => (8, 0),
        BoundKind::WebbEnhanced(k) => (9, k as u32),
        BoundKind::Cascade => (10, 0),
        BoundKind::KeoghRev => (11, 0),
        BoundKind::UcrCascade => (12, 0),
    }
}

fn decode_bound(tag: u32, k: u32) -> Option<BoundKind> {
    Some(match tag {
        0 => BoundKind::KimFL,
        1 => BoundKind::Keogh,
        2 => BoundKind::Improved,
        3 => BoundKind::Enhanced(k as usize),
        4 => BoundKind::Petitjean,
        5 => BoundKind::PetitjeanNoLr,
        6 => BoundKind::Webb,
        7 => BoundKind::WebbNoLr,
        8 => BoundKind::WebbStar,
        9 => BoundKind::WebbEnhanced(k as usize),
        10 => BoundKind::Cascade,
        11 => BoundKind::KeoghRev,
        12 => BoundKind::UcrCascade,
        _ => return None,
    })
}

fn encode_strategy(s: SearchStrategy) -> u32 {
    match s {
        SearchStrategy::RandomOrder => 0,
        SearchStrategy::Sorted => 1,
        SearchStrategy::SortedPrecomputed => 2,
        SearchStrategy::BruteForce => 3,
    }
}

fn decode_strategy(tag: u32) -> Option<SearchStrategy> {
    Some(match tag {
        0 => SearchStrategy::RandomOrder,
        1 => SearchStrategy::Sorted,
        2 => SearchStrategy::SortedPrecomputed,
        3 => SearchStrategy::BruteForce,
        _ => return None,
    })
}

fn encode_backend(b: BackendKind) -> u32 {
    match b {
        BackendKind::None => 0,
        BackendKind::Native => 1,
        BackendKind::Pjrt => 2,
    }
}

fn decode_backend(tag: u32) -> Option<BackendKind> {
    Some(match tag {
        0 => BackendKind::None,
        1 => BackendKind::Native,
        2 => BackendKind::Pjrt,
        _ => return None,
    })
}

// ---------------------------------------------------------------------
// Save
// ---------------------------------------------------------------------

/// Serialize `index` to `path`; returns the bytes written. The snapshot
/// is self-contained: a process holding only this file can serve the
/// index (see [`load`]). Series are stored **as indexed** — when the
/// index z-normalizes, the stored values are the normalized ones, and
/// the flag only governs query-time normalization after a load.
///
/// The write is **atomic at the path**: bytes land in a sibling
/// `<path>.tmp` file which is renamed over `path` only once fully
/// written, so a crash or full disk mid-save never destroys an existing
/// good snapshot at the same path.
pub fn save(index: &DtwIndex, path: &Path) -> Result<u64, SnapshotError> {
    save_with(index, path, &crate::io::RealFs)
}

/// [`save`] through an explicit [`FileOps`](crate::io::FileOps)
/// implementation — the seam the fault-injection recovery suite
/// (`rust/tests/recovery.rs`) drives to enumerate every crash point of
/// the create/write/sync/rename sequence and prove the write is atomic
/// at `path` under all of them.
pub fn save_with(
    index: &DtwIndex,
    path: &Path,
    fs: &dyn crate::io::FileOps,
) -> Result<u64, SnapshotError> {
    let train = &*index.train;
    let n = train.len();
    let l = train.series.first().map(|s| s.len()).unwrap_or(0);
    let stride = EnvelopeStore::stride_for(l);
    let cfg = &index.config;
    // Store-less configurations (single shard + non-store backend) skip
    // the flat-store build at index construction; the snapshot payload
    // needs one, so materialize a transient single-shard partition here.
    let transient;
    let shard_list: &[ShardStore] = if index.shards.is_empty() && n > 0 {
        transient = crate::bounds::store::partition_shards(&train.series, 1);
        &transient
    } else {
        &index.shards
    };

    let mut body = Vec::with_capacity(64 + n * 4 + 2 * n * l * 8 + 2 * n * stride * 8);
    put_u32(&mut body, u32::from(cfg.znorm));
    let (bound_tag, bound_k) = encode_bound(cfg.bound);
    put_u32(&mut body, bound_tag);
    put_u32(&mut body, bound_k);
    put_u32(&mut body, encode_strategy(cfg.strategy));
    put_u32(&mut body, encode_backend(cfg.backend));
    put_u64(&mut body, cfg.max_batch as u64);
    put_u64(&mut body, cfg.seed);
    put_u64(&mut body, cfg.threads as u64);
    put_u64(&mut body, cfg.clusters as u64);
    put_u64(&mut body, cfg.generation);
    put_u64(&mut body, cfg.parent);
    put_u64(&mut body, shard_list.len() as u64);
    put_u64(&mut body, n as u64);
    put_u64(&mut body, l as u64);
    put_u64(&mut body, train.w as u64);
    put_u64(&mut body, stride as u64);
    for &label in &train.labels {
        put_u32(&mut body, label);
    }
    for s in &train.series {
        put_f64s(&mut body, &s.values);
    }
    for shard in shard_list {
        put_u64(&mut body, shard.len() as u64);
        put_f64s(&mut body, shard.store().payload());
        match shard.clusters() {
            Some(cl) => {
                put_u64(&mut body, cl.len() as u64);
                for &o in cl.offsets() {
                    put_u32(&mut body, o);
                }
                for &m in cl.members() {
                    put_u32(&mut body, m);
                }
                for &p in cl.pivots() {
                    put_u32(&mut body, p);
                }
                put_f64s(&mut body, cl.pivot_dists());
                put_f64s(&mut body, cl.env().payload());
            }
            None => put_u64(&mut body, 0),
        }
    }

    // Write-then-rename so an interrupted save never clobbers an
    // existing good snapshot at `path`; header and body stream to the
    // file separately (no second snapshot-sized buffer).
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    let write_all = |body: &[u8]| -> std::io::Result<()> {
        let mut f = fs.create(&tmp)?;
        f.write(&MAGIC)?;
        f.write(&VERSION.to_le_bytes())?;
        f.write(&fnv1a64(body).to_le_bytes())?;
        f.write(&(body.len() as u64).to_le_bytes())?;
        f.write(body)?;
        // Durable before the rename makes it visible.
        f.sync()
    };
    if let Err(e) = write_all(&body) {
        let _ = fs.remove(&tmp);
        return Err(SnapshotError::Io(e));
    }
    if let Err(e) = fs.rename(&tmp, path) {
        let _ = fs.remove(&tmp);
        return Err(SnapshotError::Io(e));
    }
    Ok(28 + body.len() as u64)
}

// ---------------------------------------------------------------------
// Load / inspect
// ---------------------------------------------------------------------

/// The validated pieces of a snapshot body, shared by [`load`] and
/// [`inspect`]. In header-only mode ([`parse`] with
/// `want_payload = false`) the payload sections are length-validated
/// and skipped — `labels`/`values`/`shards` stay empty and nothing
/// beyond the header is materialized.
struct Parsed {
    info: SnapshotInfo,
    labels: Vec<u32>,
    values: Vec<f64>,
    shards: Vec<ShardStore>,
}

/// Read + validate the envelope of the file: magic, version, length,
/// checksum. Returns the body slice, the header checksum, and the
/// format version (every version from 1 to [`VERSION`] is accepted;
/// the version steers section parsing downstream).
fn validated_body(bytes: &[u8]) -> Result<(&[u8], u64, u32), SnapshotError> {
    if bytes.len() < 12 {
        return Err(SnapshotError::Truncated { context: "file header" });
    }
    if bytes[..8] != MAGIC {
        return Err(SnapshotError::BadMagic);
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
    if !(1..=VERSION).contains(&version) {
        return Err(SnapshotError::UnsupportedVersion { found: version, supported: VERSION });
    }
    if bytes.len() < 28 {
        return Err(SnapshotError::Truncated { context: "file header" });
    }
    let stored = u64::from_le_bytes(bytes[12..20].try_into().expect("8 bytes"));
    let body_len = u64::from_le_bytes(bytes[20..28].try_into().expect("8 bytes"));
    let body = &bytes[28..];
    let body_len = usize::try_from(body_len)
        .map_err(|_| SnapshotError::Corrupt("body length overflows usize".into()))?;
    if body.len() < body_len {
        return Err(SnapshotError::Truncated { context: "snapshot body" });
    }
    if body.len() > body_len {
        return Err(SnapshotError::Corrupt(format!(
            "{} trailing bytes after the declared body",
            body.len() - body_len
        )));
    }
    let computed = fnv1a64(body);
    if computed != stored {
        return Err(SnapshotError::ChecksumMismatch { stored, computed });
    }
    Ok((body, stored, version))
}

fn parse(bytes: &[u8], want_payload: bool) -> Result<Parsed, SnapshotError> {
    let (body, checksum, version) = validated_body(bytes)?;
    let mut r = Reader::new(body);

    let flags = r.u32("flags")?;
    if flags & !1 != 0 {
        return Err(SnapshotError::Corrupt(format!("unknown flag bits {flags:#x}")));
    }
    let znorm = flags & 1 == 1;
    let bound_tag = r.u32("bound tag")?;
    let bound_k = r.u32("bound k")?;
    let bound = decode_bound(bound_tag, bound_k)
        .ok_or_else(|| SnapshotError::Corrupt(format!("unknown bound tag {bound_tag}")))?;
    let strategy_tag = r.u32("strategy tag")?;
    let strategy = decode_strategy(strategy_tag)
        .ok_or_else(|| SnapshotError::Corrupt(format!("unknown strategy tag {strategy_tag}")))?;
    let backend_tag = r.u32("backend tag")?;
    let backend = decode_backend(backend_tag)
        .ok_or_else(|| SnapshotError::Corrupt(format!("unknown backend tag {backend_tag}")))?;
    let max_batch = r.size("max_batch")?;
    let seed = r.u64("seed")?;
    let threads = r.size("threads")?;
    let clusters = if version >= 2 { r.size("clusters")? } else { 0 };
    let (generation, parent) = if version >= 3 {
        (r.u64("generation")?, r.u64("parent generation")?)
    } else {
        (0, 0)
    };
    let shard_count = r.size("shard count")?;
    let n = r.size("series count")?;
    let l = r.size("series length")?;
    let w = r.size("window")?;
    let stride = r.size("stride")?;

    if n > 0 && l == 0 {
        return Err(SnapshotError::Corrupt("non-empty index with empty series".into()));
    }
    if stride != EnvelopeStore::stride_for(l) {
        return Err(SnapshotError::Corrupt(format!(
            "stride {stride} does not match series length {l} (expected {})",
            EnvelopeStore::stride_for(l)
        )));
    }
    if (n == 0) != (shard_count == 0) {
        return Err(SnapshotError::Corrupt(format!(
            "{n} series across {shard_count} shards"
        )));
    }
    if shard_count > n {
        return Err(SnapshotError::Corrupt(format!(
            "{shard_count} shards for {n} series"
        )));
    }

    let label_bytes = n
        .checked_mul(4)
        .ok_or_else(|| SnapshotError::Corrupt("label count overflows".into()))?;
    let mut labels = Vec::new();
    if want_payload {
        // Length before allocation: the checksum does not vouch for
        // honesty (FNV is not cryptographic), so a crafted header's n
        // must fail typed, never panic on the reserve.
        if r.remaining() < label_bytes {
            return Err(SnapshotError::Truncated { context: "labels" });
        }
        labels.reserve_exact(n);
        for _ in 0..n {
            labels.push(r.u32("labels")?);
        }
    } else {
        r.take(label_bytes, "labels")?;
    }
    let n_values = n
        .checked_mul(l)
        .ok_or_else(|| SnapshotError::Corrupt("series shape overflows".into()))?;
    let values = if want_payload {
        r.f64s(n_values, "series values")?
    } else {
        r.take(
            n_values
                .checked_mul(8)
                .ok_or_else(|| SnapshotError::Corrupt("series shape overflows".into()))?,
            "series values",
        )?;
        Vec::new()
    };

    // Every shard section starts with an 8-byte size: bound the shard
    // vector's reserve by the bytes actually present.
    let shard_header_bytes = shard_count
        .checked_mul(8)
        .ok_or_else(|| SnapshotError::Corrupt("shard count overflows".into()))?;
    if shard_header_bytes > r.remaining() {
        return Err(SnapshotError::Truncated { context: "shard sizes" });
    }
    let mut shards = Vec::with_capacity(if want_payload { shard_count } else { 0 });
    let mut start = 0usize;
    for _ in 0..shard_count {
        let shard_n = r.size("shard size")?;
        if shard_n == 0 {
            return Err(SnapshotError::Corrupt("empty shard".into()));
        }
        let payload_bytes = 2usize
            .checked_mul(shard_n)
            .and_then(|x| x.checked_mul(stride))
            .and_then(|x| x.checked_mul(8))
            .ok_or_else(|| SnapshotError::Corrupt("shard payload overflows".into()))?;
        let raw = r.take(payload_bytes, "shard payload")?;
        if want_payload {
            // Decode straight into the fresh 64-byte-aligned allocation
            // — no intermediate Vec<f64>.
            let store = EnvelopeStore::from_le_payload(shard_n, l, raw)
                .map_err(SnapshotError::Corrupt)?;
            shards.push(ShardStore::new(start, store));
        }
        // v2+: the shard's cluster section. A v1 file simply has none —
        // it loads as a clusterless shard.
        if version >= 2 {
            let k = r.size("cluster count")?;
            if k > shard_n {
                return Err(SnapshotError::Corrupt(format!(
                    "{k} clusters for a {shard_n}-series shard"
                )));
            }
            if k > 0 {
                // Bound the section's allocations by the bytes present
                // before trusting k (same discipline as the shard loop).
                let section_bytes = (k + 1 + shard_n + k)
                    .checked_mul(4)
                    .and_then(|x| x.checked_add(shard_n * 8))
                    .and_then(|x| x.checked_add(2 * k * stride * 8))
                    .ok_or_else(|| SnapshotError::Corrupt("cluster section overflows".into()))?;
                if section_bytes > r.remaining() {
                    return Err(SnapshotError::Truncated { context: "cluster section" });
                }
                let offsets = r.u32s(k + 1, "cluster offsets")?;
                let members = r.u32s(shard_n, "cluster members")?;
                let pivots = r.u32s(k, "cluster pivots")?;
                let pivot_dist = r.f64s(shard_n, "cluster pivot distances")?;
                let env_raw = r.take(2 * k * stride * 8, "cluster envelopes")?;
                if want_payload {
                    let env = EnvelopeStore::from_le_payload(k, l, env_raw)
                        .map_err(SnapshotError::Corrupt)?;
                    let cl =
                        ShardClusters::from_parts(shard_n, members, offsets, pivots, pivot_dist, env)
                            .map_err(SnapshotError::Corrupt)?;
                    let shard = shards.pop().expect("shard pushed above").with_clusters(cl);
                    shards.push(shard);
                }
            }
        }
        start += shard_n;
    }
    if start != n {
        return Err(SnapshotError::Corrupt(format!(
            "shards cover {start} series, header says {n}"
        )));
    }
    if !r.exhausted() {
        return Err(SnapshotError::Corrupt("trailing bytes in body".into()));
    }

    Ok(Parsed {
        info: SnapshotInfo {
            version,
            checksum,
            bytes: bytes.len() as u64,
            series: n,
            series_len: l,
            window: w,
            shards: shard_count,
            bound,
            strategy,
            backend,
            znorm,
            max_batch,
            threads,
            seed,
            clusters,
            generation,
            parent,
        },
        labels,
        values,
        shards,
    })
}

/// Read the header of the snapshot at `path` (after verifying its
/// checksum and internal consistency) — the `index inspect` entry
/// point. Payload sections are length-validated and skipped, never
/// decoded or materialized.
pub fn inspect(path: &Path) -> Result<SnapshotInfo, SnapshotError> {
    inspect_with(path, &crate::io::RealFs)
}

/// [`inspect`] through an explicit [`FileOps`](crate::io::FileOps)
/// implementation (fault-injection and in-memory test doubles).
pub fn inspect_with(
    path: &Path,
    fs: &dyn crate::io::FileOps,
) -> Result<SnapshotInfo, SnapshotError> {
    let bytes = fs.read(path)?;
    Ok(parse(&bytes, false)?.info)
}

/// The auto-versioned snapshot path for one generation of a live index:
/// `<base>.g<N>`. The router's `save=` verb writes every generation to
/// its own file under this naming, so `load=<base>.g<N>` can roll back
/// to any retained generation while later generations keep their own
/// files.
pub fn generation_path(base: &Path, generation: u64) -> std::path::PathBuf {
    let mut name = base.as_os_str().to_owned();
    name.push(format!(".g{generation}"));
    std::path::PathBuf::from(name)
}

/// Deserialize the snapshot at `path` into a ready-to-serve
/// [`DtwIndex`]. Per-shard envelope stores are restored with one bulk
/// copy each; per-series envelopes are **views copied out of those
/// stores** (the exact bits that were saved), and only the
/// envelope-of-envelope pair is recomputed — a deterministic pure
/// function of the stored envelopes, so search results are bit-equal to
/// the saved index by construction.
pub fn load(path: &Path) -> Result<DtwIndex, SnapshotError> {
    load_with(path, &crate::io::RealFs)
}

/// [`load`] through an explicit [`FileOps`](crate::io::FileOps)
/// implementation — lets the recovery suite load the exact bytes a
/// simulated crash left behind.
pub fn load_with(
    path: &Path,
    fs: &dyn crate::io::FileOps,
) -> Result<DtwIndex, SnapshotError> {
    let bytes = fs.read(path)?;
    let Parsed { info, labels, values, shards } = parse(&bytes, true)?;
    let (n, l, w) = (info.series, info.series_len, info.window);

    let mut series = Vec::with_capacity(n);
    for shard in &shards {
        let store = shard.store();
        for t_local in 0..store.len() {
            let t = shard.start() + t_local;
            let vals = values[t * l..(t + 1) * l].to_vec();
            let lo = store.lo_row(t_local).to_vec();
            let up = store.up_row(t_local).to_vec();
            // Exactly PreparedSeries::prepare's derivation, from the
            // *stored* envelopes.
            let (lo_of_up, _) = envelope::envelopes(&up, w);
            let (_, up_of_lo) = envelope::envelopes(&lo, w);
            series.push(PreparedSeries { values: vals, w, lo, up, lo_of_up, up_of_lo });
        }
    }

    Ok(DtwIndex {
        train: Arc::new(PreparedTrainSet { labels, series, w }),
        shards: Arc::new(shards),
        config: IndexConfig {
            bound: info.bound,
            strategy: info.strategy,
            backend: info.backend,
            max_batch: info.max_batch,
            znorm: info.znorm,
            seed: info.seed,
            threads: info.threads,
            clusters: info.clusters,
            generation: info.generation,
            parent: info.parent,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enum_tags_round_trip() {
        for &b in BoundKind::ALL {
            let (tag, k) = encode_bound(b);
            assert_eq!(decode_bound(tag, k), Some(b), "{b}");
        }
        // Parameterized families keep their k payload.
        let (tag, k) = encode_bound(BoundKind::Enhanced(5));
        assert_eq!(decode_bound(tag, k), Some(BoundKind::Enhanced(5)));
        assert_eq!(decode_bound(99, 0), None);
        for &s in SearchStrategy::ALL {
            assert_eq!(decode_strategy(encode_strategy(s)), Some(s), "{s}");
        }
        assert_eq!(decode_strategy(99), None);
        for b in [BackendKind::None, BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(decode_backend(encode_backend(b)), Some(b));
        }
        assert_eq!(decode_backend(99), None);
    }

    #[test]
    fn fnv_is_stable() {
        // Pin the hash so snapshots stay readable across builds.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn checksum_valid_but_lying_header_fails_typed_not_panicking() {
        // FNV is not cryptographic: a crafted file can carry a valid
        // checksum over a header that lies about its counts. Declaring
        // 2^61 series with no payload must fail with Truncated — never
        // panic or abort on a count-sized allocation.
        let mut body = Vec::new();
        put_u32(&mut body, 0); // flags
        put_u32(&mut body, 6); // bound: Webb
        put_u32(&mut body, 0); // bound k
        put_u32(&mut body, 1); // strategy: Sorted
        put_u32(&mut body, 1); // backend: Native
        put_u64(&mut body, 16); // max_batch
        put_u64(&mut body, 0); // seed
        put_u64(&mut body, 1); // threads
        put_u64(&mut body, 0); // clusters
        put_u64(&mut body, 0); // generation
        put_u64(&mut body, 0); // parent generation
        put_u64(&mut body, 1); // shard count
        put_u64(&mut body, 1u64 << 61); // n — absurd
        put_u64(&mut body, 1); // l
        put_u64(&mut body, 1); // w
        put_u64(&mut body, 8); // stride
        let mut file = Vec::new();
        file.extend_from_slice(&MAGIC);
        file.extend_from_slice(&VERSION.to_le_bytes());
        file.extend_from_slice(&fnv1a64(&body).to_le_bytes());
        file.extend_from_slice(&(body.len() as u64).to_le_bytes());
        file.extend_from_slice(&body);
        assert!(matches!(parse(&file, true), Err(SnapshotError::Truncated { .. })));
        assert!(matches!(parse(&file, false), Err(SnapshotError::Truncated { .. })));
    }

    #[test]
    fn version1_snapshot_loads_as_clusterless() {
        // Hand-write a version-1 file (no clusters field, no per-shard
        // cluster sections) from a real index's parts: it must load
        // cleanly as a clusterless index with bit-identical payload.
        let series: Vec<Vec<f64>> = (0..7)
            .map(|i| (0..32).map(|t| ((i * 31 + t * 7) % 13) as f64 * 0.25 - 1.5).collect())
            .collect();
        let index = DtwIndex::builder(series).window(3).build().unwrap();
        let train = &*index.train;
        let (n, l) = (train.len(), 32usize);
        let stride = EnvelopeStore::stride_for(l);

        let mut body = Vec::new();
        put_u32(&mut body, 0); // flags: no znorm
        let (bt, bk) = encode_bound(index.config.bound);
        put_u32(&mut body, bt);
        put_u32(&mut body, bk);
        put_u32(&mut body, encode_strategy(index.config.strategy));
        put_u32(&mut body, encode_backend(index.config.backend));
        put_u64(&mut body, index.config.max_batch as u64);
        put_u64(&mut body, index.config.seed);
        put_u64(&mut body, index.config.threads as u64);
        // v1: no clusters field here.
        put_u64(&mut body, index.shards.len() as u64);
        put_u64(&mut body, n as u64);
        put_u64(&mut body, l as u64);
        put_u64(&mut body, train.w as u64);
        put_u64(&mut body, stride as u64);
        for &label in &train.labels {
            put_u32(&mut body, label);
        }
        for s in &train.series {
            put_f64s(&mut body, &s.values);
        }
        for shard in index.shards.iter() {
            put_u64(&mut body, shard.len() as u64);
            put_f64s(&mut body, shard.store().payload());
            // v1: no cluster section here.
        }
        let mut file = Vec::new();
        file.extend_from_slice(&MAGIC);
        file.extend_from_slice(&1u32.to_le_bytes());
        file.extend_from_slice(&fnv1a64(&body).to_le_bytes());
        file.extend_from_slice(&(body.len() as u64).to_le_bytes());
        file.extend_from_slice(&body);

        let path = std::env::temp_dir().join(format!("dtwb_v1_{}.snap", std::process::id()));
        std::fs::write(&path, &file).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.version, 1);
        assert_eq!(info.clusters, 0);
        let loaded = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.clusters(), 0);
        assert!(!loaded.has_clusters());
        assert_eq!(loaded.len(), index.len());
        for (a, b) in index.train.series.iter().zip(loaded.train.series.iter()) {
            assert_eq!(a.values, b.values);
            assert_eq!(a.lo, b.lo);
            assert_eq!(a.up, b.up);
        }
    }

    #[test]
    fn version2_round_trip_preserves_clusters_bit_exactly() {
        let series: Vec<Vec<f64>> = (0..13)
            .map(|i| (0..24).map(|t| ((i * 17 + t * 5) % 11) as f64 * 0.5 - 2.0).collect())
            .collect();
        let index = DtwIndex::builder(series)
            .window(2)
            .shards(3)
            .clusters(2)
            .build()
            .unwrap();
        assert!(index.has_clusters());
        let path = std::env::temp_dir().join(format!("dtwb_v2cl_{}.snap", std::process::id()));
        index.save(&path).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.version, VERSION);
        assert_eq!(info.clusters, 2);
        let loaded = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.clusters(), 2);
        for (a, b) in index.shards.iter().zip(loaded.shards.iter()) {
            let (ca, cb) = (a.clusters().unwrap(), b.clusters().unwrap());
            assert_eq!(ca.members(), cb.members());
            assert_eq!(ca.offsets(), cb.offsets());
            assert_eq!(ca.pivots(), cb.pivots());
            // Raw-bit compare: INFINITY (abandoned pivot DTW) and every
            // finite distance must survive the trip exactly.
            let bits = |d: &[f64]| d.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(ca.pivot_dists()), bits(cb.pivot_dists()));
            assert_eq!(bits(ca.env().payload()), bits(cb.env().payload()));
        }
    }

    #[test]
    fn version3_round_trips_generation_and_parent() {
        let series: Vec<Vec<f64>> = (0..5)
            .map(|i| (0..16).map(|t| ((i * 7 + t * 3) % 9) as f64 - 4.0).collect())
            .collect();
        let mut index = DtwIndex::builder(series).window(2).build().unwrap();
        assert_eq!((index.generation(), index.parent()), (0, 0));
        index.config.generation = 4;
        index.config.parent = 3;
        let path = std::env::temp_dir().join(format!("dtwb_v3gen_{}.snap", std::process::id()));
        index.save(&path).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.version, VERSION);
        assert_eq!((info.generation, info.parent), (4, 3));
        let loaded = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!((loaded.generation(), loaded.parent()), (4, 3));
    }

    #[test]
    fn version2_snapshot_loads_as_generation_zero() {
        // Hand-write a version-2 file (clusters field present, no
        // generation pair): it must load as generation 0, parent 0.
        let series: Vec<Vec<f64>> = (0..6)
            .map(|i| (0..20).map(|t| ((i * 13 + t * 3) % 7) as f64 * 0.5).collect())
            .collect();
        let index = DtwIndex::builder(series).window(2).build().unwrap();
        let train = &*index.train;
        let (n, l) = (train.len(), 20usize);
        let stride = EnvelopeStore::stride_for(l);

        let mut body = Vec::new();
        put_u32(&mut body, 0); // flags: no znorm
        let (bt, bk) = encode_bound(index.config.bound);
        put_u32(&mut body, bt);
        put_u32(&mut body, bk);
        put_u32(&mut body, encode_strategy(index.config.strategy));
        put_u32(&mut body, encode_backend(index.config.backend));
        put_u64(&mut body, index.config.max_batch as u64);
        put_u64(&mut body, index.config.seed);
        put_u64(&mut body, index.config.threads as u64);
        put_u64(&mut body, 0); // clusters — v2 has this…
        // …but no generation/parent pair (v3+ only).
        put_u64(&mut body, index.shards.len() as u64);
        put_u64(&mut body, n as u64);
        put_u64(&mut body, l as u64);
        put_u64(&mut body, train.w as u64);
        put_u64(&mut body, stride as u64);
        for &label in &train.labels {
            put_u32(&mut body, label);
        }
        for s in &train.series {
            put_f64s(&mut body, &s.values);
        }
        for shard in index.shards.iter() {
            put_u64(&mut body, shard.len() as u64);
            put_f64s(&mut body, shard.store().payload());
            put_u64(&mut body, 0); // cluster count
        }
        let mut file = Vec::new();
        file.extend_from_slice(&MAGIC);
        file.extend_from_slice(&2u32.to_le_bytes());
        file.extend_from_slice(&fnv1a64(&body).to_le_bytes());
        file.extend_from_slice(&(body.len() as u64).to_le_bytes());
        file.extend_from_slice(&body);

        let path = std::env::temp_dir().join(format!("dtwb_v2gen_{}.snap", std::process::id()));
        std::fs::write(&path, &file).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.version, 2);
        assert_eq!((info.generation, info.parent), (0, 0));
        let loaded = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!((loaded.generation(), loaded.parent()), (0, 0));
        assert_eq!(loaded.len(), index.len());
    }

    #[test]
    fn generation_path_appends_suffix() {
        let p = generation_path(Path::new("/var/lib/dtwb/prod.snap"), 7);
        assert_eq!(p, Path::new("/var/lib/dtwb/prod.snap.g7"));
    }

    #[test]
    fn envelope_validation_rejects_bad_files() {
        assert!(matches!(
            validated_body(b"short"),
            Err(SnapshotError::Truncated { .. })
        ));
        let mut not_magic = vec![0u8; 64];
        not_magic[..8].copy_from_slice(b"NOTMAGIC");
        assert!(matches!(validated_body(&not_magic), Err(SnapshotError::BadMagic)));

        let mut future = Vec::new();
        future.extend_from_slice(&MAGIC);
        future.extend_from_slice(&(VERSION + 1).to_le_bytes());
        future.extend_from_slice(&[0u8; 16]);
        assert!(matches!(
            validated_body(&future),
            Err(SnapshotError::UnsupportedVersion { found, .. }) if found == VERSION + 1
        ));

        // Valid envelope around a 4-byte body, then corrupt one byte.
        let body = 7u32.to_le_bytes();
        let mut ok = Vec::new();
        ok.extend_from_slice(&MAGIC);
        ok.extend_from_slice(&VERSION.to_le_bytes());
        ok.extend_from_slice(&fnv1a64(&body).to_le_bytes());
        ok.extend_from_slice(&(body.len() as u64).to_le_bytes());
        ok.extend_from_slice(&body);
        assert!(validated_body(&ok).is_ok());
        let mut flipped = ok.clone();
        *flipped.last_mut().unwrap() ^= 0x40;
        assert!(matches!(
            validated_body(&flipped),
            Err(SnapshotError::ChecksumMismatch { .. })
        ));
        let mut short = ok.clone();
        short.truncate(ok.len() - 2);
        assert!(matches!(
            validated_body(&short),
            Err(SnapshotError::Truncated { context: "snapshot body" })
        ));
        let mut long = ok;
        long.push(0);
        assert!(matches!(validated_body(&long), Err(SnapshotError::Corrupt(_))));
    }
}
