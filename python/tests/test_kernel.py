"""L1 correctness: Pallas kernels vs the pure-numpy oracles.

The hypothesis sweeps are the core correctness signal for the kernel
layer: shapes, windows and value ranges are generated adversarially and
every result is checked against ``ref.py``.
"""

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import lb_keogh as kernels
from compile.kernels import ref

RNG = np.random.default_rng(20210707)


def random_batch(b, l, scale=1.0):
    return (RNG.standard_normal((b, l)) * scale).astype(np.float32)


class TestLbKeoghKernel:
    @pytest.mark.parametrize("b,n,l", [(8, 8, 16), (8, 16, 64), (16, 8, 128), (24, 24, 32)])
    @pytest.mark.parametrize("w", [1, 4])
    def test_matches_ref_on_grid(self, b, n, l, w):
        q = random_batch(b, l)
        t = random_batch(n, l)
        lo, up = ref.envelopes_ref(t, w)
        got = np.asarray(kernels.lb_keogh(q, lo.astype(np.float32), up.astype(np.float32)))
        want = ref.lb_keogh_matrix_ref(q, lo, up)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_when_inside_envelope(self):
        q = np.zeros((8, 32), dtype=np.float32)
        lo = -np.ones((8, 32), dtype=np.float32)
        up = np.ones((8, 32), dtype=np.float32)
        got = np.asarray(kernels.lb_keogh(q, lo, up))
        np.testing.assert_array_equal(got, np.zeros((8, 8)))

    def test_padding_envelope_contributes_zero(self):
        # The Rust runtime pads length with q=0 inside [-BIG, BIG].
        q = random_batch(8, 64)
        t = random_batch(8, 64)
        lo, up = ref.envelopes_ref(t, 2)
        base = np.asarray(kernels.lb_keogh(q, lo.astype(np.float32), up.astype(np.float32)))
        pad = 32
        qp = np.concatenate([q, np.zeros((8, pad), np.float32)], axis=1)
        lop = np.concatenate([lo, np.full((8, pad), -ref.BIG)], axis=1).astype(np.float32)
        upp = np.concatenate([up, np.full((8, pad), ref.BIG)], axis=1).astype(np.float32)
        padded = np.asarray(kernels.lb_keogh(qp, lop, upp))
        np.testing.assert_allclose(padded, base, rtol=1e-5)

    @settings(max_examples=40, deadline=None)
    @given(
        b=st.sampled_from([8, 16]),
        n=st.sampled_from([8, 16]),
        l=st.integers(min_value=4, max_value=96),
        w=st.integers(min_value=0, max_value=12),
        scale=st.floats(min_value=0.1, max_value=100.0),
    )
    def test_hypothesis_sweep(self, b, n, l, w, scale):
        q = random_batch(b, l, scale)
        t = random_batch(n, l, scale)
        lo, up = ref.envelopes_ref(t, w)
        got = np.asarray(kernels.lb_keogh(q, lo.astype(np.float32), up.astype(np.float32)))
        want = ref.lb_keogh_matrix_ref(q, lo, up)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-3 * scale * scale)


class TestEnvelopeKernel:
    @pytest.mark.parametrize("n,l", [(8, 16), (16, 64), (8, 128)])
    @pytest.mark.parametrize("w", [0, 1, 3, 9])
    def test_matches_ref(self, n, l, w):
        x = random_batch(n, l)
        lo, up = kernels.envelopes(x, w)
        lo_ref, up_ref = ref.envelopes_ref(x, w)
        np.testing.assert_allclose(np.asarray(lo), lo_ref, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(up), up_ref, rtol=1e-6)

    def test_window_larger_than_series(self):
        x = random_batch(8, 12)
        lo, up = kernels.envelopes(x, 50)
        assert np.allclose(np.asarray(lo), x.min(axis=1, keepdims=True))
        assert np.allclose(np.asarray(up), x.max(axis=1, keepdims=True))

    @settings(max_examples=30, deadline=None)
    @given(
        l=st.integers(min_value=2, max_value=80),
        w=st.integers(min_value=0, max_value=20),
    )
    def test_hypothesis_sweep(self, l, w):
        x = random_batch(8, l)
        lo, up = kernels.envelopes(x, w)
        lo_ref, up_ref = ref.envelopes_ref(x, w)
        np.testing.assert_allclose(np.asarray(lo), lo_ref, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(up), up_ref, rtol=1e-6)


class TestRefInvariants:
    """The oracle itself honors the paper's invariants."""

    @settings(max_examples=25, deadline=None)
    @given(
        l=st.integers(min_value=2, max_value=40),
        w=st.integers(min_value=0, max_value=10),
    )
    def test_lb_keogh_lower_bounds_dtw(self, l, w):
        a = RNG.standard_normal(l)
        b = RNG.standard_normal(l)
        lo, up = ref.envelopes_ref(b[None, :], w)
        lb = ref.lb_keogh_row_ref(a, lo[0], up[0])
        d = ref.dtw_ref(a, b, w)
        assert lb <= d + 1e-9

    def test_dtw_figure3(self):
        # The paper's running example (caption says 52; the recurrence
        # yields 53 - see EXPERIMENTS.md "Paper discrepancies").
        A = [-1, 1, -1, 4, -2, 1, 1, 1, -1, 0, 1]
        B = [1, -1, 1, -1, -1, -4, -4, -1, 1, 0, -1]
        assert ref.dtw_ref(np.array(A), np.array(B), 1) == 53.0
