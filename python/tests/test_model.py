"""L2 correctness: the model graph, kernel composition and AOT lowering."""

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng(42)


class TestModel:
    def test_batch_lb_keogh_matches_ref(self):
        q = RNG.standard_normal((8, 64)).astype(np.float32)
        t = RNG.standard_normal((16, 64)).astype(np.float32)
        lo, up = ref.envelopes_ref(t, 3)
        (got,) = model.batch_lb_keogh(q, lo.astype(np.float32), up.astype(np.float32))
        want = ref.lb_keogh_matrix_ref(q, lo, up)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)

    def test_from_series_composes_kernels(self):
        q = RNG.standard_normal((8, 32)).astype(np.float32)
        t = RNG.standard_normal((8, 32)).astype(np.float32)
        (got,) = model.batch_lb_keogh_from_series(q, t, w=2)
        lo, up = ref.envelopes_ref(t, 2)
        want = ref.lb_keogh_matrix_ref(q, lo, up)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


class TestAot:
    def test_lowered_hlo_text_is_parseable_hlo(self):
        text = aot.lower_lb_keogh(8, 8, 16)
        assert "ENTRY" in text
        assert "f32[8,16]" in text  # query parameter shape
        assert "f32[8,8]" in text   # output shape

    def test_shapes_table_is_sane(self):
        for (b, n, l) in aot.SHAPES:
            assert b % 8 == 0 and n % 8 == 0
            assert l >= 16

    @pytest.mark.slow
    def test_roundtrip_numerics_via_jax_executable(self):
        # Compile the lowered module with jax's own client and compare -
        # the same HLO the Rust side loads.
        import jax

        q = RNG.standard_normal((8, 16)).astype(np.float32)
        t = RNG.standard_normal((8, 16)).astype(np.float32)
        lo, up = ref.envelopes_ref(t, 1)
        compiled = jax.jit(model.batch_lb_keogh).lower(
            jax.ShapeDtypeStruct((8, 16), np.float32),
            jax.ShapeDtypeStruct((8, 16), np.float32),
            jax.ShapeDtypeStruct((8, 16), np.float32),
        ).compile()
        (got,) = compiled(q, lo.astype(np.float32), up.astype(np.float32))
        want = ref.lb_keogh_matrix_ref(q, lo, up)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
