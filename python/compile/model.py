"""Layer-2 JAX model: the batched lower-bound scoring graph.

The Rust coordinator offloads its screening pass here: one XLA execution
scores a whole query batch against a whole training set. Two entry points:

* :func:`batch_lb_keogh` - queries + precomputed envelopes -> bound
  matrix. This is the artifact the Rust runtime loads (envelopes are
  precomputed on the Rust side exactly once per training set).
* :func:`batch_lb_keogh_from_series` - queries + raw training series +
  window; computes the envelopes with the Pallas envelope kernel first.
  Used when the caller has no precomputed envelopes (and as an
  integration test of kernel composition).

Both lower into a single HLO module containing the Pallas kernels
(interpret=True -> plain HLO ops, runnable on the CPU PJRT client).
"""

from __future__ import annotations

import jax

from .kernels import lb_keogh as kernels


def batch_lb_keogh(q: jax.Array, lo: jax.Array, up: jax.Array):
    """Bound matrix ``[B, N]`` for queries ``[B, L]`` and envelopes ``[N, L]``.

    Returned as a 1-tuple: artifacts are lowered with ``return_tuple=True``
    and unpacked with ``to_tuple`` on the Rust side.
    """
    return (kernels.lb_keogh(q, lo, up),)


def batch_lb_keogh_from_series(q: jax.Array, t: jax.Array, *, w: int):
    """Bound matrix computed from raw training series ``t`` ``[N, L]``."""
    lo, up = kernels.envelopes(t, w)
    return (kernels.lb_keogh(q, lo, up),)
