"""Layer-1 Pallas kernels."""
