"""Pure-numpy oracles for the Pallas kernels.

Every kernel in this package is validated against these references at
build time (``pytest python/tests``). They are deliberately written in
the most obvious way possible — clarity over speed.
"""

from __future__ import annotations

import numpy as np

BIG = 1e30


def envelopes_ref(x: np.ndarray, w: int) -> tuple[np.ndarray, np.ndarray]:
    """Warping envelopes of a batch of series.

    Args:
      x: ``[n, l]`` series.
      w: window half-width.

    Returns:
      ``(lower, upper)``, each ``[n, l]``:
      ``upper[i, j] = max(x[i, max(0, j-w) : j+w+1])`` and the min for
      ``lower`` — the U^S / L^S of the paper (section 3).
    """
    x = np.asarray(x)
    n, l = x.shape
    lo = np.empty_like(x)
    up = np.empty_like(x)
    for i in range(n):
        for j in range(l):
            a = max(0, j - w)
            b = min(l, j + w + 1)
            lo[i, j] = x[i, a:b].min()
            up[i, j] = x[i, a:b].max()
    return lo, up


def lb_keogh_row_ref(q: np.ndarray, lo: np.ndarray, up: np.ndarray) -> float:
    """Scalar LB_Keogh (squared delta) of one query against one envelope."""
    above = np.maximum(q - up, 0.0)
    below = np.maximum(lo - q, 0.0)
    d = above + below  # at most one of the two is nonzero per element
    return float(np.sum(d * d))


def lb_keogh_matrix_ref(q: np.ndarray, lo: np.ndarray, up: np.ndarray) -> np.ndarray:
    """Batched LB_Keogh matrix.

    Args:
      q: ``[b, l]`` queries.
      lo: ``[n, l]`` training lower envelopes.
      up: ``[n, l]`` training upper envelopes.

    Returns:
      ``[b, n]`` with ``out[i, t] = LB_Keogh(q[i], envelope(t))``.
    """
    q = np.asarray(q, dtype=np.float64)
    lo = np.asarray(lo, dtype=np.float64)
    up = np.asarray(up, dtype=np.float64)
    b, l = q.shape
    n, l2 = lo.shape
    assert l == l2 and up.shape == lo.shape
    out = np.empty((b, n), dtype=np.float64)
    for i in range(b):
        for t in range(n):
            out[i, t] = lb_keogh_row_ref(q[i], lo[t], up[t])
    return out


def dtw_ref(a: np.ndarray, b: np.ndarray, w: int) -> float:
    """Windowed DTW with squared delta — oracle for end-to-end tests
    (mirrors the Rust implementation and paper Eq. 2)."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    la, lb = len(a), len(b)
    w = max(w, abs(la - lb))
    D = np.full((la, lb), np.inf)
    for i in range(la):
        for j in range(max(0, i - w), min(lb, i + w + 1)):
            d = (a[i] - b[j]) ** 2
            if i == 0 and j == 0:
                best = 0.0
            else:
                cands = []
                if i > 0 and j > 0:
                    cands.append(D[i - 1, j - 1])
                if j > 0:
                    cands.append(D[i, j - 1])
                if i > 0:
                    cands.append(D[i - 1, j])
                best = min(cands)
            D[i, j] = d + best
    return float(D[la - 1, lb - 1])
