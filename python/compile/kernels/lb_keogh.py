"""Layer-1 Pallas kernels: batched LB_Keogh and warping envelopes.

Hardware adaptation (DESIGN.md section "Hardware-Adaptation"): the paper's
hot loop is a scalar, branchy CPU sweep. On TPU-shaped hardware the same
computation is a branch-free clip-and-reduce, so:

* ``lb_keogh`` tiles the (query-batch x training-rows) plane; each program
  holds a ``[TB, L]`` query tile and a ``[TN, L]`` envelope tile in VMEM
  and reduces ``max(q-up, lo-q, 0)^2`` over the series axis on the VPU —
  one HBM pass per operand, no data-dependent control flow.
* ``envelopes`` replaces the Lemire deque (sequential, scalar, hostile to
  vector units) with a shifted-stack windowed min/max: ``O(l*w)`` FLOPs
  instead of ``O(l)``, but fully vectorized — the classic CPU-to-
  accelerator trade.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO that both pytest and the
Rust runtime execute. Real-TPU performance is *estimated* from the
BlockSpec footprint in DESIGN.md, not measured.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30

# Tile-size policy. The kernel materializes a [TB, TN, L] f32 clip tile;
# we budget it at ~4 MiB — on TPU that fits VMEM (~16 MiB/core) with room
# to double-buffer the operand tiles, and on the CPU interpret path it
# maximizes L2/L3 locality while amortizing per-grid-step overhead
# (measured in EXPERIMENTS.md #Perf: 8x8 tiles ran 8x slower than 32x64
# at 32x256x512).
TILE_BUDGET_BYTES = 4 << 20
MAX_TB = 32
TN_ENVELOPE = 8  # envelope kernel rows per program


def _divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (>= 1)."""
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


def _tiles(b: int, n: int, l: int) -> tuple[int, int]:
    """Pick (TB, TN) for the bound-matrix kernel."""
    tb = _divisor_at_most(b, MAX_TB)
    budget_rows = max(1, TILE_BUDGET_BYTES // (tb * l * 4))
    tn = _divisor_at_most(n, budget_rows)
    return tb, tn


def _lb_keogh_kernel(q_ref, lo_ref, up_ref, out_ref):
    """One (TB x TN) output tile.

    q_ref: [TB, L] queries; lo_ref/up_ref: [TN, L] envelopes;
    out_ref: [TB, TN] bound values.
    """
    q = q_ref[...]            # [TB, L]
    lo = lo_ref[...]          # [TN, L]
    up = up_ref[...]          # [TN, L]
    qe = q[:, None, :]        # [TB, 1, L]
    above = jnp.maximum(qe - up[None, :, :], 0.0)   # [TB, TN, L]
    below = jnp.maximum(lo[None, :, :] - qe, 0.0)
    d = above + below         # disjoint support
    out_ref[...] = jnp.sum(d * d, axis=-1)


@functools.partial(jax.jit, static_argnames=())
def lb_keogh(q: jax.Array, lo: jax.Array, up: jax.Array) -> jax.Array:
    """Batched LB_Keogh matrix via Pallas.

    Args:
      q: ``[B, L]`` float32 queries (B divisible by TB).
      lo: ``[N, L]`` float32 lower envelopes (N divisible by TN).
      up: ``[N, L]`` float32 upper envelopes.

    Returns:
      ``[B, N]`` float32, ``out[i, t] = LB_Keogh(q[i], env t)`` with
      squared delta.
    """
    b, l = q.shape
    n, _ = lo.shape
    tb, tn = _tiles(b, n, l)
    grid = (b // tb, n // tn)
    return pl.pallas_call(
        _lb_keogh_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, l), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, l), lambda i, j: (j, 0)),
            pl.BlockSpec((tn, l), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), q.dtype),
        interpret=True,
    )(q, lo, up)


def _envelope_kernel(x_ref, lo_ref, up_ref, *, w: int, l: int):
    """Windowed min/max over the series axis by shifted stacking.

    x_ref: [TN, L]; lo_ref/up_ref: [TN, L] outputs. ``w`` is static.
    """
    x = x_ref[...]
    lo = x
    up = x
    # Shift by +/- s with edge padding; O(w) vector ops of length L.
    for s in range(1, w + 1):
        left = jnp.concatenate([x[:, :1].repeat(s, axis=1), x[:, : l - s]], axis=1)
        right = jnp.concatenate([x[:, s:], x[:, -1:].repeat(s, axis=1)], axis=1)
        # Edge padding repeats the boundary element, which is already in
        # every window that clips the boundary - harmless for min/max.
        lo = jnp.minimum(lo, jnp.minimum(left, right))
        up = jnp.maximum(up, jnp.maximum(left, right))
    lo_ref[...] = lo
    up_ref[...] = up


@functools.partial(jax.jit, static_argnames=("w",))
def envelopes(x: jax.Array, w: int) -> tuple[jax.Array, jax.Array]:
    """Warping envelopes ``(lower, upper)`` of ``[N, L]`` series, window w."""
    n, l = x.shape
    tn = _divisor_at_most(n, TN_ENVELOPE)
    w = min(w, l - 1)  # shifts beyond the series length are no-ops
    kernel = functools.partial(_envelope_kernel, w=w, l=l)
    lo, up = pl.pallas_call(
        kernel,
        grid=(n // tn,),
        in_specs=[pl.BlockSpec((tn, l), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tn, l), lambda i: (i, 0)),
            pl.BlockSpec((tn, l), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, l), x.dtype),
            jax.ShapeDtypeStruct((n, l), x.dtype),
        ],
        interpret=True,
    )(x)
    return lo, up
