"""AOT compiler: lower the L2 model (with its L1 Pallas kernels) to HLO
text artifacts the Rust runtime loads.

HLO **text** is the interchange format: jax >= 0.5 serializes
HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot [--out-dir ../artifacts]``.
Emits one ``lb_keogh`` artifact per shape in SHAPES plus ``manifest.tsv``
(``name<TAB>batch<TAB>rows<TAB>len<TAB>file``).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (query batch, training rows, series length). Shapes are static under
# XLA; the Rust BatchLb pads smaller workloads up to the best fit.
SHAPES = [
    (8, 64, 128),
    (16, 128, 256),
    (32, 256, 512),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_lb_keogh(b: int, n: int, l: int) -> str:
    q = jax.ShapeDtypeStruct((b, l), jnp.float32)
    env = jax.ShapeDtypeStruct((n, l), jnp.float32)
    lowered = jax.jit(model.batch_lb_keogh).lower(q, env, env)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest_lines = ["# name\tbatch\trows\tlen\tfile"]
    for (b, n, l) in SHAPES:
        fname = f"lb_keogh_{b}x{n}x{l}.hlo.txt"
        path = os.path.join(out_dir, fname)
        text = lower_lb_keogh(b, n, l)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"lb_keogh\t{b}\t{n}\t{l}\t{fname}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {out_dir}/manifest.tsv ({len(SHAPES)} artifacts)")


if __name__ == "__main__":
    main()
