//! Minimal offline shim of the `anyhow` crate (see `vendor/README.md`).
//!
//! Implements the subset of `anyhow`'s API that `dtw-bounds` uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! [`anyhow!`], [`bail!`] and [`ensure!`] macros. `{e}` prints the
//! outermost context frame, `{e:#}` the full `outer: ...: root` chain —
//! matching the real crate's Display behaviour.
//!
//! Swap the `[dependencies]` path entry for the real crate when building
//! with network access; no call sites change.

use std::fmt;

/// A context-carrying error: an ordered chain of frames, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Prepend a context frame (what [`Context::context`] expands to).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) frame.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

macro_rules! impl_from {
    ($($ty:ty),* $(,)?) => {
        $(
            impl From<$ty> for Error {
                fn from(e: $ty) -> Error {
                    Error::msg(e)
                }
            }
        )*
    };
}

impl_from!(
    std::io::Error,
    std::fmt::Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::num::TryFromIntError,
    std::str::Utf8Error,
    std::string::FromUtf8Error,
    std::net::AddrParseError,
    std::time::SystemTimeError,
    std::array::TryFromSliceError,
    std::char::ParseCharError,
    std::str::ParseBoolError,
    String,
    &str,
);

impl From<Box<dyn std::error::Error + Send + Sync + 'static>> for Error {
    fn from(e: Box<dyn std::error::Error + Send + Sync + 'static>) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — a [`Result`](std::result::Result) defaulting the
/// error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error with a context frame.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-evaluated context frame.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a single displayable
/// expression (mirrors the real crate's two forms).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chain_formats() {
        let e = io_err().context("opening manifest").unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn with_context_on_option() {
        let none: Option<u8> = None;
        let e = none.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<u32> {
            let n: u32 = "12x".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_compose() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{:#}", f(false).unwrap_err()), "flag was false");
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        let e: Result<()> = Err(anyhow!("root"));
        let e = e.context("mid").unwrap_err().context("outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
    }

    #[test]
    fn anyhow_macro_forms() {
        let x = 3;
        assert_eq!(format!("{}", anyhow!("captured {x}")), "captured 3");
        assert_eq!(format!("{}", anyhow!("positional {}", 4)), "positional 4");
        let msg = String::from("from expr");
        assert_eq!(format!("{}", anyhow!(msg)), "from expr");
    }
}
