//! Minimal offline shim of the `log` facade crate (see
//! `vendor/README.md`): the five level macros, the [`Log`] trait, and the
//! global logger/level registry. Behaviour matches the real crate for the
//! subset used here: records below `max_level()` are dropped before the
//! logger is consulted, and the logger can be installed exactly once.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single record.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 1,
    /// Suspicious but survivable conditions.
    Warn = 2,
    /// High-level progress.
    Info = 3,
    /// Developer detail.
    Debug = 4,
    /// Extremely verbose tracing.
    Trace = 5,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Global verbosity cap; `Off` drops everything.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    /// Drop every record.
    Off = 0,
    /// `Error` only.
    Error = 1,
    /// `Warn` and below.
    Warn = 2,
    /// `Info` and below.
    Info = 3,
    /// `Debug` and below.
    Debug = 4,
    /// Everything.
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        Some((*self as usize).cmp(&(*other as usize)))
    }
}

/// Metadata about a record: its level and target module path.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    /// The record's level.
    pub fn level(&self) -> Level {
        self.level
    }

    /// The record's target (module path at the macro call site).
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// The record's level.
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    /// The record's target module path.
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    /// The record's metadata.
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    /// The message, ready to pass to a formatting macro.
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink. Implementations must be thread-safe.
pub trait Log: Send + Sync {
    /// Whether a record with this metadata would be logged.
    fn enabled(&self, metadata: &Metadata) -> bool;

    /// Consume one record.
    fn log(&self, record: &Record);

    /// Flush buffered output.
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Returned by [`set_logger`] when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger (once per process).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity cap.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global verbosity cap.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record { metadata: Metadata { level, target }, args };
            if logger.enabled(&record.metadata) {
                logger.log(&record);
            }
        }
    }
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, m: &Metadata) -> bool {
            m.level() <= max_level()
        }
        fn log(&self, r: &Record) {
            let _ = format!("{} {}", r.level(), r.args());
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn filtering_and_dispatch() {
        static COUNTER: Counter = Counter;
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Warn);
        let before = HITS.load(Ordering::Relaxed);
        warn!("w {}", 1);
        info!("dropped");
        error!("e");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 2);
        assert!(Level::Warn <= LevelFilter::Warn);
        assert!(!(Level::Info <= LevelFilter::Warn));
        assert_eq!(format!("{}", Level::Info), "INFO");
    }
}
