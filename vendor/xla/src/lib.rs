//! Compile-time **stub** of the `xla` crate's PJRT API surface (see
//! `vendor/README.md`).
//!
//! Exists so the `pjrt` cargo feature of `dtw-bounds` type-checks without
//! the real `xla` crate (which needs crates.io access plus the
//! `xla_extension` C++ artifacts — neither is available in the offline
//! build). Every runtime entry point returns [`stub_err`]; callers detect
//! this at `PjRtClient::cpu()` and fall back to the native backend.
//!
//! Mirrors the call shapes of `xla` 0.1.x / `xla_extension` 0.5.1 as used
//! by `dtw_bounds::runtime::client`.

use anyhow::Result;

fn stub_err<T>(what: &str) -> Result<T> {
    anyhow::bail!(
        "xla stub: {what} unavailable (vendor/xla is a compile-time placeholder; \
         link the real `xla` crate and xla_extension artifacts for PJRT execution)"
    )
}

/// PJRT client handle (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client — always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        stub_err("PJRT CPU client")
    }

    /// Platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file — always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err("HLO text parsing")
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host inputs — always fails in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("execute")
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal — always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("buffer transfer")
    }
}

/// A host tensor literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    /// Destructure a tuple literal — always fails in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub_err("tuple destructuring")
    }

    /// Copy out as a typed host vector — always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub_err("literal readback")
    }
}

/// An array shape (stub).
pub struct Shape {
    _private: (),
}

impl Shape {
    /// Array shape with element type `T`.
    pub fn array<T>(_dims: Vec<i64>) -> Shape {
        Shape { _private: () }
    }
}

/// Graph builder (stub).
pub struct XlaBuilder {
    _private: (),
}

impl XlaBuilder {
    /// New builder for a named computation.
    pub fn new(_name: &str) -> XlaBuilder {
        XlaBuilder { _private: () }
    }

    /// Declare a shaped parameter — always fails in the stub.
    pub fn parameter_s(&self, _id: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        stub_err("builder ops")
    }

    /// Tuple several ops — always fails in the stub.
    pub fn tuple(&self, _ops: &[XlaOp]) -> Result<XlaOp> {
        stub_err("builder ops")
    }
}

/// A node in a computation under construction (stub).
pub struct XlaOp {
    _private: (),
}

impl XlaOp {
    /// Elementwise addition — always fails in the stub.
    pub fn add_(&self, _rhs: &XlaOp) -> Result<XlaOp> {
        stub_err("builder ops")
    }

    /// Finalize the enclosing builder into a computation — always fails
    /// in the stub.
    pub fn build(&self) -> Result<XlaComputation> {
        stub_err("builder ops")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_at_the_entry_point() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(format!("{err:#}").contains("xla stub"), "{err:#}");
    }

    #[test]
    fn literal_packing_is_shape_only() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
