//! Persistence & sharding trajectory bench: cold-start latency of the
//! snapshot path and k-NN throughput per shard count.
//!
//! Two measurements land in `BENCH_index_persist.json`:
//!
//! * **cold_start** — milliseconds from process state to a
//!   ready-to-serve [`DtwIndex`]: `load` (snapshot → index, the
//!   `serve --snapshot` path: length check + bulk copy per shard, plus
//!   the envelope-of-envelope pass) vs `rebuild` (raw series → index,
//!   the no-snapshot baseline paying full envelope preparation). The
//!   snapshot byte size rides along so storage cost is visible in the
//!   trajectory too.
//! * **shard_scaling** — queries/sec of the sharded k-NN search at
//!   1/2/4 shards (× the thread grid), same workload, same neighbors —
//!   shards only move the fan-out.
//!
//! Knobs (env): `DTWB_REPEATS` (default 3), `DTWB_SERIES_LEN` (256),
//! `DTWB_CANDIDATES` (400), `DTWB_QUERIES` (24), `DTWB_THREADS` (4).
//!
//! ```sh
//! cargo bench --bench index_persist
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::index::{DtwIndex, QueryOptions};
use dtw_bounds::metrics::{Summary, Table};

/// Smooth random-walk series (same workload family as `dtw_kernel`).
fn walk(rng: &mut Rng, l: usize) -> Vec<f64> {
    let mut v = 0.0;
    (0..l)
        .map(|_| {
            v += rng.normal() * 0.5;
            v
        })
        .collect()
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let l = env_usize("DTWB_SERIES_LEN", 256);
    let n = env_usize("DTWB_CANDIDATES", 400);
    let nq = env_usize("DTWB_QUERIES", 24);
    let threads = env_usize("DTWB_THREADS", 4);
    let w = (l / 10).max(1);
    let mut rng = Rng::seeded(0x5A7E);

    let train: Vec<Vec<f64>> = (0..n).map(|_| walk(&mut rng, l)).collect();
    let queries: Vec<Vec<f64>> = (0..nq).map(|_| walk(&mut rng, l)).collect();
    let snap_path = std::env::temp_dir()
        .join(format!("dtwb_bench_persist_{}.snap", std::process::id()));

    // ----------------------------------------------------------------
    // Cold start: snapshot load vs raw rebuild.
    // ----------------------------------------------------------------
    benchkit::banner(&format!(
        "Cold start to a ready index (l={l}, w={w}, n={n}, 2 shards)"
    ));
    let reference = DtwIndex::builder(train.clone())
        .window(w)
        .shards(2)
        .build()
        .expect("one shared length");
    let bytes = reference.save(&snap_path).expect("write snapshot");

    let rebuild_ms = Summary::of(&benchkit::time_reps(knobs.repeats, || {
        let idx = DtwIndex::builder(train.clone())
            .window(w)
            .shards(2)
            .build()
            .expect("one shared length");
        std::hint::black_box(idx.len());
    }))
    .mean
        * 1e3;
    let load_ms = Summary::of(&benchkit::time_reps(knobs.repeats, || {
        let idx = DtwIndex::load(&snap_path).expect("read snapshot");
        std::hint::black_box(idx.len());
    }))
    .mean
        * 1e3;

    let mut cold_table = Table::new(vec!["phase", "ms", "vs rebuild"]);
    cold_table.row(vec![
        "rebuild".into(),
        format!("{rebuild_ms:.2}"),
        "1.00x".into(),
    ]);
    cold_table.row(vec![
        "load".into(),
        format!("{load_ms:.2}"),
        format!("{:.2}x", rebuild_ms / load_ms.max(1e-9)),
    ]);
    println!("{}", cold_table.to_markdown());
    println!("(snapshot: {bytes} bytes on disk)");
    let cold_records = vec![
        benchkit::ColdStartRecord {
            phase: "rebuild".into(),
            series: n,
            series_len: l,
            shards: 2,
            bytes: 0,
            millis: rebuild_ms,
        },
        benchkit::ColdStartRecord {
            phase: "load".into(),
            series: n,
            series_len: l,
            shards: 2,
            bytes,
            millis: load_ms,
        },
    ];

    // Sanity: the loaded index must answer exactly like the reference
    // (cheap spot check so a broken trajectory never goes unnoticed).
    let loaded = DtwIndex::load(&snap_path).expect("read snapshot");
    let a = reference.knn::<Squared>(&queries[0], 3);
    let b = loaded.knn::<Squared>(&queries[0], 3);
    assert_eq!(a.distances(), b.distances(), "snapshot must be bit-equal");

    // ----------------------------------------------------------------
    // Sharded k-NN throughput at 1/2/4 shards.
    // ----------------------------------------------------------------
    benchkit::banner(&format!(
        "Sharded k-NN queries/sec (k=3, LB_Webb, threads={threads})"
    ));
    let mut scaling_table = Table::new(vec!["shards", "threads", "queries/s", "vs 1 shard"]);
    let mut scaling_records: Vec<benchkit::ShardScalingRecord> = Vec::new();
    let mut base_qps = 0.0f64;
    for &shards in &[1usize, 2, 4] {
        let index = DtwIndex::builder(train.clone())
            .window(w)
            .shards(shards)
            .threads(threads)
            .build()
            .expect("one shared length");
        let mut searcher = index.searcher();
        let opts = QueryOptions::k(3);
        let mean = Summary::of(&benchkit::time_reps(knobs.repeats, || {
            let mut acc = 0usize;
            for q in &queries {
                acc += searcher.query_values::<Squared>(q, &opts).neighbors.len();
            }
            std::hint::black_box(acc);
        }))
        .mean;
        let qps = nq as f64 / mean;
        if shards == 1 {
            base_qps = qps;
        }
        scaling_table.row(vec![
            shards.to_string(),
            threads.to_string(),
            format!("{qps:.1}"),
            format!("{:.2}x", qps / base_qps),
        ]);
        scaling_records.push(benchkit::ShardScalingRecord {
            shards,
            threads,
            queries: nq,
            queries_per_sec: qps,
        });
    }
    println!("{}", scaling_table.to_markdown());

    std::fs::remove_file(&snap_path).ok();

    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the trajectory file at the workspace root regardless.
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_index_persist.json");
    benchkit::write_index_persist_json(out_path, &cold_records, &scaling_records)
        .expect("write BENCH_index_persist.json");
    println!(
        "wrote BENCH_index_persist.json ({} cold-start + {} shard-scaling records)",
        cold_records.len(),
        scaling_records.len()
    );
}
