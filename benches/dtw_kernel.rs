//! Exact-DTW kernel + parallel-executor trajectory bench: the perf
//! baseline for the hardware-speed hot paths.
//!
//! Three measurements land in `BENCH_dtw_kernel.json`:
//!
//! * **cells/sec** of the three exact-DTW kernel variants on a windowed
//!   nearest-neighbor workload (each call early-abandons against the
//!   best-so-far distance, exactly like the search loops): `scalar`
//!   (`dtw_ea`), `pruned` (`dtw_ea_pruned`, live-column-range
//!   pruning), `pruned+cascade` (pruned plus the `LB_KEOGH`
//!   cumulative-lower-bound tail, tail computation included in the
//!   time). Throughput counts the *logical* band cells of every call,
//!   so skipped cells show up as higher cells/sec.
//! * **queries/sec** of the end-to-end k-NN search path at 1/2/4/8
//!   worker threads (`DtwIndexBuilder::threads`) — the executor
//!   scaling curve. Neighbors are identical at every thread count;
//!   this tracks only the speed.
//! * **cells/sec per `BoundKind` screen** (`"bounds"` array) — the
//!   source of the cells/sec column on the bound-selection table in
//!   `rust/src/bounds/mod.rs`.
//!
//! Knobs (env): `DTWB_REPEATS` (default 3), `DTWB_SERIES_LEN` (256),
//! `DTWB_CANDIDATES` (200), `DTWB_QUERIES` (24).
//!
//! ```sh
//! cargo bench --bench dtw_kernel
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::{keogh, PreparedSeries};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::dtw::{dtw_ea, dtw_ea_pruned, effective_window};
use dtw_bounds::index::{DtwIndex, QueryOptions};
use dtw_bounds::metrics::{Summary, Table};

/// Banded DP cells of one (l × l, half-window w) DTW evaluation.
fn band_cells(l: usize, w: usize) -> usize {
    let w = effective_window(l, l, w);
    (0..l).map(|i| (i + w).min(l - 1) - i.saturating_sub(w) + 1).sum()
}

/// Smooth random-walk series — adjacent candidates stay close enough
/// that bounds and pruning have real work to do.
fn walk(rng: &mut Rng, l: usize) -> Vec<f64> {
    let mut v = 0.0;
    (0..l)
        .map(|_| {
            v += rng.normal() * 0.5;
            v
        })
        .collect()
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let l = env_usize("DTWB_SERIES_LEN", 256);
    let n = env_usize("DTWB_CANDIDATES", 200);
    let nq = env_usize("DTWB_QUERIES", 24);
    let w = (l / 10).max(1);
    let mut rng = Rng::seeded(0xD7B4);

    let train: Vec<Vec<f64>> = (0..n).map(|_| walk(&mut rng, l)).collect();
    let prepared: Vec<PreparedSeries> =
        train.iter().map(|s| PreparedSeries::prepare(s.clone(), w)).collect();
    let queries: Vec<Vec<f64>> = (0..nq).map(|_| walk(&mut rng, l)).collect();

    benchkit::banner(&format!(
        "Exact-DTW kernels on the windowed NN workload (l={l}, w={w}, n={n}, q={nq})"
    ));
    let cells = band_cells(l, w) as f64;
    let total_calls = (nq * n) as f64;
    let mut table = Table::new(vec!["kernel", "Gcells/s", "vs scalar"]);
    let mut kernel_records: Vec<benchkit::DtwKernelRecord> = Vec::new();
    let mut scalar_rate = 0.0f64;

    // Each variant runs the same NN loop: candidates in order, cutoff =
    // best finite distance so far (the search kernels' exact shape).
    fn nn_sweep_mean<F: FnMut(&[f64], &PreparedSeries, f64) -> f64>(
        queries: &[Vec<f64>],
        prepared: &[PreparedSeries],
        repeats: usize,
        mut kernel: F,
    ) -> f64 {
        Summary::of(&benchkit::time_reps(repeats, || {
            let mut acc = 0.0;
            for q in queries {
                let mut best = f64::INFINITY;
                for t in prepared {
                    let d = kernel(q, t, best);
                    if d.is_finite() && d < best {
                        best = d;
                    }
                }
                acc += best;
            }
            std::hint::black_box(acc);
        }))
        .mean
    }

    let mut tail = Vec::new();
    let means: Vec<(&str, f64)> = vec![
        (
            "scalar",
            nn_sweep_mean(&queries, &prepared, knobs.repeats, |q, t, cut| {
                dtw_ea::<Squared>(q, &t.values, w, cut)
            }),
        ),
        (
            "pruned",
            nn_sweep_mean(&queries, &prepared, knobs.repeats, |q, t, cut| {
                dtw_ea_pruned::<Squared>(q, &t.values, w, cut, None)
            }),
        ),
        (
            "pruned+cascade",
            nn_sweep_mean(&queries, &prepared, knobs.repeats, |q, t, cut| {
                if cut.is_finite() {
                    keogh::lb_keogh_tail::<Squared>(q, &t.lo, &t.up, &mut tail);
                    dtw_ea_pruned::<Squared>(q, &t.values, w, cut, Some(&tail))
                } else {
                    dtw_ea_pruned::<Squared>(q, &t.values, w, cut, None)
                }
            }),
        ),
    ];

    for (name, mean) in means {
        let rate = total_calls * cells / mean;
        if name == "scalar" {
            scalar_rate = rate;
        }
        table.row(vec![
            name.to_string(),
            format!("{:.3}", rate / 1e9),
            format!("{:.2}x", rate / scalar_rate),
        ]);
        kernel_records.push(benchkit::DtwKernelRecord {
            kernel: name.to_string(),
            series_len: l,
            window: w,
            cells_per_sec: rate,
        });
    }
    println!("{}", table.to_markdown());
    println!("(cells/sec counts every call's full band — pruned/abandoned cells count as done)");

    benchkit::banner("k-NN search thread scaling (sorted strategy, LB_Webb screen)");
    let mut scaling_table = Table::new(vec!["threads", "queries/s", "speedup"]);
    let mut scaling_records: Vec<benchkit::ThreadScalingRecord> = Vec::new();
    let mut base_qps = 0.0f64;
    for &threads in &[1usize, 2, 4, 8] {
        let index = DtwIndex::builder(train.clone())
            .window(w)
            .threads(threads)
            .build()
            .expect("one shared length");
        let mut searcher = index.searcher();
        let opts = QueryOptions::k(3);
        let mean = Summary::of(&benchkit::time_reps(knobs.repeats, || {
            let mut acc = 0usize;
            for q in &queries {
                acc += searcher.query_values::<Squared>(q, &opts).neighbors.len();
            }
            std::hint::black_box(acc);
        }))
        .mean;
        let qps = nq as f64 / mean;
        if threads == 1 {
            base_qps = qps;
        }
        scaling_table.row(vec![
            threads.to_string(),
            format!("{qps:.1}"),
            format!("{:.2}x", qps / base_qps),
        ]);
        scaling_records.push(benchkit::ThreadScalingRecord {
            threads,
            queries: nq,
            queries_per_sec: qps,
        });
    }
    println!("{}", scaling_table.to_markdown());

    benchkit::banner("Per-bound screening throughput (cells/sec, one query x candidate pair)");
    // The source of the cells/sec column on BoundKind's
    // tightness-vs-cost table (rust/src/bounds/mod.rs).
    use dtw_bounds::bounds::{BoundKind, Scratch};
    let mut bound_table = Table::new(vec!["bound", "Mcells/s"]);
    let mut bound_records: Vec<benchkit::BoundScreenRecord> = Vec::new();
    let mut scratch = Scratch::new(l);
    let pq_cache: Vec<PreparedSeries> =
        queries.iter().map(|q| PreparedSeries::prepare(q.clone(), w)).collect();
    for &bound in BoundKind::ALL {
        let iters = 200_000 / (l.max(1)) + 1;
        let ns = benchkit::ns_per_call(iters, || {
            let mut acc = 0.0;
            for (pq, t) in pq_cache.iter().zip(prepared.iter()) {
                acc += bound.compute::<Squared>(pq, t, w, f64::INFINITY, &mut scratch);
            }
            acc
        }) / pq_cache.len().min(prepared.len()).max(1) as f64;
        let rate = l as f64 / ns * 1e9;
        bound_table.row(vec![bound.name(), format!("{:.1}", rate / 1e6)]);
        bound_records.push(benchkit::BoundScreenRecord {
            bound: bound.name(),
            series_len: l,
            cells_per_sec: rate,
        });
    }
    println!("{}", bound_table.to_markdown());

    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the trajectory file at the workspace root regardless.
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_dtw_kernel.json");
    benchkit::write_dtw_kernel_json(out_path, &kernel_records, &scaling_records, &bound_records)
        .expect("write BENCH_dtw_kernel.json");
    println!(
        "wrote BENCH_dtw_kernel.json ({} kernel + {} scaling + {} bound records)",
        kernel_records.len(),
        scaling_records.len(),
        bound_records.len()
    );
}
