//! Perf-regression gate over the exact-DTW kernel trajectory:
//! compares a fresh `BENCH_dtw_kernel.json` (emitted by
//! `cargo bench --bench dtw_kernel`) against the committed
//! `benches/baseline.json` and fails when throughput dropped more than
//! [`TOLERANCE`] (20%) on any matched entry.
//!
//! * queries/sec entries match on `threads`; cells/sec entries match on
//!   `kernel` name.
//! * An empty baseline (the seed state) passes with a note on how to
//!   record one; extra/missing entries warn but never fail.
//! * `DTWB_REGRESSION_WARN_ONLY=1` downgrades failures to warnings —
//!   what CI sets while the perf trajectory is young (shared runners
//!   are noisy); flip it off once baselines stabilize.
//!
//! ```sh
//! cargo bench --bench dtw_kernel          # emit BENCH_dtw_kernel.json
//! cargo bench --bench check_regression    # gate against the baseline
//! cp BENCH_dtw_kernel.json benches/baseline.json   # record a baseline
//! ```
//!
//! The parser handles exactly the flat shape `benchkit`'s
//! `write_dtw_kernel_json` emits (one record per line) — no serde in
//! the offline build.

/// Allowed fractional throughput drop before the gate trips.
const TOLERANCE: f64 = 0.20;

/// Extract `"key": <number>` from a JSON-ish line.
fn num_field(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find(|c: char| c == ',' || c == '}').unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// Extract `"key": "<string>"` from a JSON-ish line.
fn str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\": \"");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    Some(rest[..rest.find('"')?].to_string())
}

/// `(label, throughput)` per record: kernel records keyed
/// `kernel:<name>`, scaling records keyed `threads:<n>`.
fn parse_records(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        if let (Some(kernel), Some(rate)) =
            (str_field(line, "kernel"), num_field(line, "cells_per_sec"))
        {
            out.push((format!("kernel:{kernel}"), rate));
        } else if let (Some(bound), Some(rate)) =
            (str_field(line, "bound"), num_field(line, "cells_per_sec"))
        {
            out.push((format!("bound:{bound}"), rate));
        } else if let (Some(threads), Some(qps)) =
            (num_field(line, "threads"), num_field(line, "queries_per_sec"))
        {
            out.push((format!("threads:{threads}"), qps));
        }
    }
    out
}

fn main() {
    let warn_only = std::env::var("DTWB_REGRESSION_WARN_ONLY").map(|v| v == "1").unwrap_or(false);
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor both files at their committed/emitted locations instead.
    let baseline_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../benches/baseline.json");
    let current_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_dtw_kernel.json");

    let baseline = match std::fs::read_to_string(baseline_path) {
        Ok(t) => parse_records(&t),
        Err(e) => {
            println!("regression check: cannot read {baseline_path} ({e}); nothing to gate");
            return;
        }
    };
    if baseline.is_empty() {
        println!(
            "regression check: {baseline_path} holds no entries yet — record one with\n  \
             cargo bench --bench dtw_kernel && cp {current_path} {baseline_path}"
        );
        return;
    }
    let current = match std::fs::read_to_string(current_path) {
        Ok(t) => parse_records(&t),
        Err(e) => {
            println!(
                "regression check: cannot read {current_path} ({e}); \
                 run `cargo bench --bench dtw_kernel` first"
            );
            std::process::exit(if warn_only { 0 } else { 1 });
        }
    };

    let mut regressions = 0usize;
    for (label, base) in &baseline {
        match current.iter().find(|(l, _)| l == label) {
            None => println!("  WARN {label}: present in baseline, missing from current run"),
            Some((_, now)) => {
                let ratio = now / base;
                let verdict = if ratio < 1.0 - TOLERANCE {
                    regressions += 1;
                    "REGRESSION"
                } else {
                    "ok"
                };
                println!("  {verdict} {label}: baseline {base:.1}, current {now:.1} ({ratio:.2}x)");
            }
        }
    }
    for (label, _) in &current {
        if !baseline.iter().any(|(l, _)| l == label) {
            println!("  note {label}: new entry (not in baseline)");
        }
    }

    if regressions > 0 {
        let msg = format!(
            "regression check: {regressions} entr{} dropped more than {:.0}%",
            if regressions == 1 { "y" } else { "ies" },
            TOLERANCE * 100.0
        );
        if warn_only {
            println!("{msg} (DTWB_REGRESSION_WARN_ONLY=1: not failing)");
        } else {
            eprintln!("{msg}");
            std::process::exit(1);
        }
    } else {
        println!("regression check: all matched entries within {:.0}%", TOLERANCE * 100.0);
    }
}
