//! PJRT batched prefilter vs the scalar Rust loop: pairs/second of
//! `LB_KEOGH` screening at the compiled artifact shapes. Requires
//! `make artifacts` (skips politely otherwise).
//!
//! ```sh
//! make artifacts && cargo bench --bench runtime_batch
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::{keogh, PreparedSeries};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::metrics::{Summary, Table};
use dtw_bounds::runtime::{default_artifacts_dir, read_manifest, BatchLb, XlaRuntime};

fn main() {
    let dir = default_artifacts_dir();
    let manifest = match read_manifest(&dir) {
        Ok(m) => m,
        Err(_) => {
            println!("no artifacts under {} — run `make artifacts` first", dir.display());
            return;
        }
    };
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let knobs = benchkit::Knobs::from_env();
    let mut rng = Rng::seeded(0x0DDB);

    benchkit::banner("Batched XLA LB_Keogh vs scalar Rust (pairs/s)");
    let mut table = Table::new(vec![
        "shape (b x n x l)",
        "scalar Ms pairs/s",
        "xla Ms pairs/s",
        "speedup",
    ]);

    for entry in manifest.iter().filter(|e| e.name == "lb_keogh") {
        let (b, n, l) = (entry.batch, entry.rows, entry.len);
        let w = (l / 10).max(1);
        let queries: Vec<Vec<f64>> =
            (0..b).map(|_| (0..l).map(|_| rng.normal()).collect()).collect();
        let train: Vec<PreparedSeries> = (0..n)
            .map(|_| PreparedSeries::prepare((0..l).map(|_| rng.normal()).collect(), w))
            .collect();

        // Scalar Rust: b*n bound computations.
        let scalar_times = benchkit::time_reps(knobs.repeats, || {
            let mut acc = 0.0;
            for q in &queries {
                for t in &train {
                    acc += keogh::lb_keogh::<Squared>(q, t, f64::INFINITY);
                }
            }
            std::hint::black_box(acc);
        });

        // XLA batch: one execution.
        let mut blb = BatchLb::load(&rt, &dir, b, n, l).expect("artifact loads");
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let lo_refs: Vec<&[f64]> = train.iter().map(|t| t.lo.as_slice()).collect();
        let up_refs: Vec<&[f64]> = train.iter().map(|t| t.up.as_slice()).collect();
        let xla_times = benchkit::time_reps(knobs.repeats, || {
            let m = blb.compute(&q_refs, &lo_refs, &up_refs).expect("compute");
            std::hint::black_box(m.len());
        });

        let pairs = (b * n) as f64;
        let s_rate = pairs / Summary::of(&scalar_times).mean / 1e6;
        let x_rate = pairs / Summary::of(&xla_times).mean / 1e6;
        table.row(vec![
            format!("{b} x {n} x {l}"),
            format!("{s_rate:.2}"),
            format!("{x_rate:.2}"),
            format!("{:.2}x", x_rate / s_rate),
        ]);
    }
    println!("{}", table.to_markdown());
    println!("(scalar path includes early-abandon branching; the XLA path is branch-free f32.)");
}
