//! Batched `LB_KEOGH` screening backends vs the scalar per-pair loop:
//! pairs/second at serving-relevant shapes, plus a machine-readable
//! `BENCH_runtime_batch.json` (bound name, series length, candidates,
//! ns/op) so the perf trajectory of the native backend is tracked across
//! PRs.
//!
//! ```sh
//! cargo bench --bench runtime_batch                    # scalar + native
//! cargo bench --bench runtime_batch --features pjrt    # + XLA (needs `make artifacts`)
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::{keogh, PreparedSeries};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::metrics::{Summary, Table};
use dtw_bounds::runtime::{LbBackend, NativeBatchLb};

/// (query batch, candidates, series length) — the shapes the AOT
/// artifacts are compiled for, so native and pjrt numbers are comparable.
const SHAPES: &[(usize, usize, usize)] = &[(8, 64, 128), (16, 128, 256), (32, 256, 512)];

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let mut rng = Rng::seeded(0x0DDB);

    benchkit::banner("Batched LB_Keogh screening: backends vs scalar Rust (pairs/s)");
    let mut table =
        Table::new(vec!["backend", "shape (b x n x l)", "Ms pairs/s", "vs scalar"]);
    let mut records: Vec<benchkit::BenchRecord> = Vec::new();

    for &(b, n, l) in SHAPES {
        let w = (l / 10).max(1);
        let queries: Vec<Vec<f64>> =
            (0..b).map(|_| (0..l).map(|_| rng.normal()).collect()).collect();
        let train: Vec<PreparedSeries> = (0..n)
            .map(|_| PreparedSeries::prepare((0..l).map(|_| rng.normal()).collect(), w))
            .collect();
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; b];
        let pairs = (b * n) as f64;
        let shape = format!("{b} x {n} x {l}");

        // Scalar baseline: b*n independent kernel calls, query-major (the
        // pre-backend layout — every query streams all candidates).
        let scalar_mean = Summary::of(&benchkit::time_reps(knobs.repeats, || {
            let mut acc = 0.0;
            for q in &queries {
                for t in &train {
                    acc += keogh::lb_keogh::<Squared>(q, t, f64::INFINITY);
                }
            }
            std::hint::black_box(acc);
        }))
        .mean;
        let scalar_rate = pairs / scalar_mean / 1e6;
        table.row(vec![
            "scalar".to_string(),
            shape.clone(),
            format!("{scalar_rate:.2}"),
            "1.00x".to_string(),
        ]);
        records.push(benchkit::BenchRecord {
            bound: "lb_keogh/scalar".to_string(),
            series_len: l,
            candidates: n,
            ns_per_op: scalar_mean * 1e9 / pairs,
        });

        // Native backend: cache-blocked over candidates.
        let mut native = NativeBatchLb::new();
        let native_mean = Summary::of(&benchkit::time_reps(knobs.repeats, || {
            let m = native.compute(&q_refs, &train, &cutoffs).expect("native compute");
            std::hint::black_box(m.len());
        }))
        .mean;
        let native_rate = pairs / native_mean / 1e6;
        table.row(vec![
            "native".to_string(),
            shape.clone(),
            format!("{native_rate:.2}"),
            format!("{:.2}x", native_rate / scalar_rate),
        ]);
        records.push(benchkit::BenchRecord {
            bound: "lb_keogh/native".to_string(),
            series_len: l,
            candidates: n,
            ns_per_op: native_mean * 1e9 / pairs,
        });

        #[cfg(feature = "pjrt")]
        bench_pjrt(
            &mut table,
            &mut records,
            &q_refs,
            &train,
            (b, n, l),
            knobs.repeats,
            scalar_rate,
        );
    }

    println!("{}", table.to_markdown());
    println!("(the scalar path includes early-abandon branching; batched paths are branch-free)");
    benchkit::write_json("BENCH_runtime_batch.json", &records)
        .expect("write BENCH_runtime_batch.json");
    println!("wrote BENCH_runtime_batch.json ({} records)", records.len());
}

/// PJRT/XLA backend timing (one execution per batch). Skips politely
/// when artifacts or the runtime are unavailable.
#[cfg(feature = "pjrt")]
fn bench_pjrt(
    table: &mut Table,
    records: &mut Vec<benchkit::BenchRecord>,
    q_refs: &[&[f64]],
    train: &[PreparedSeries],
    (b, n, l): (usize, usize, usize),
    repeats: usize,
    scalar_rate: f64,
) {
    use dtw_bounds::runtime::{default_artifacts_dir, BatchLb, XlaRuntime};

    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        println!("pjrt: no artifacts under {} — run `make artifacts`", dir.display());
        return;
    }
    let rt = match XlaRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("pjrt: runtime unavailable ({e:#})");
            return;
        }
    };
    let mut blb = match BatchLb::load(&rt, &dir, b, n, l) {
        Ok(blb) => blb,
        Err(e) => {
            println!("pjrt: no artifact fits {b}x{n}x{l} ({e:#})");
            return;
        }
    };
    let cutoffs = vec![f64::INFINITY; q_refs.len()];
    let pairs = (b * n) as f64;
    let mean = Summary::of(&benchkit::time_reps(repeats, || {
        let m = blb.compute(q_refs, train, &cutoffs).expect("pjrt compute");
        std::hint::black_box(m.len());
    }))
    .mean;
    let rate = pairs / mean / 1e6;
    table.row(vec![
        "pjrt".to_string(),
        format!("{b} x {n} x {l}"),
        format!("{rate:.2}"),
        format!("{:.2}x", rate / scalar_rate),
    ]);
    records.push(benchkit::BenchRecord {
        bound: "lb_keogh/pjrt".to_string(),
        series_len: l,
        candidates: n,
        ns_per_op: mean * 1e9 / pairs,
    });
}
