//! Figures 31–34 (§7): the effect of the left/right paths.
//!
//! Compares `LB_WEBB` vs `LB_WEBB_NoLR` (tightness Fig 31, time Fig 33)
//! and vs `LB_WEBB_ENHANCED³` (tightness Fig 32, time Fig 34) at
//! recommended windows, sorted-order search.
//!
//! ```sh
//! cargo bench --bench fig_lr_ablation
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec};
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::nn_timing::win_loss_ratio;
use dtw_bounds::experiments::{lr_ablation, with_recommended_window};
use dtw_bounds::metrics::format_duration;

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let archive = generate_archive(&ArchiveSpec::new(knobs.scale, knobs.seed));
    let datasets = with_recommended_window(&archive);
    let take = knobs.take_of(datasets.len(), usize::MAX);
    let datasets = &datasets[..take];
    benchkit::banner(&format!(
        "Left/right path ablation — {} datasets, {} repeats (Figures 31-34)",
        datasets.len(),
        knobs.repeats
    ));

    let res = lr_ablation::<Squared>(datasets, knobs.repeats, knobs.seed);

    println!("tightness matrix (Figures 31, 32):");
    println!("{}", res.tightness.to_table().to_csv());
    let (w31, l31) = res.tightness.win_loss(BoundKind::Webb, BoundKind::WebbNoLr);
    let (w32, l32) = res.tightness.win_loss(BoundKind::Webb, BoundKind::WebbEnhanced(3));
    println!("Fig 31: Webb tighter than Webb_NoLR on {w31}, less on {l31}");
    println!("Fig 32: Webb tighter than Webb_Enhanced3 on {w32}, less on {l32}");

    println!("\nsorted NN time (Figures 33, 34):");
    for c in &res.timing {
        println!("  {:<20} total {}", c.label, format_duration(c.total()));
    }
    let (w33, l33, r33) = win_loss_ratio(&res.timing[0], &res.timing[1]);
    let (w34, l34, r34) = win_loss_ratio(&res.timing[0], &res.timing[2]);
    println!("Fig 33: Webb vs Webb_NoLR      : {w33}/{l33}, ratio {r33:.2}");
    println!("Fig 34: Webb vs Webb_Enhanced3 : {w34}/{l34}, ratio {r34:.2}");

    // §7's hard claim, asserted on this run: paths never lose to bands.
    assert_eq!(l32, 0, "LB_Webb must be at least as tight as LB_Webb_Enhanced3 everywhere");
}
