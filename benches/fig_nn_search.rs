//! Figures 19–28 (§6.2): nearest-neighbor search time at recommended
//! windows, random order (Algorithm 3) and sorted (Algorithm 4).
//!
//! Emits per-dataset mean±std scatter data (the paper's log-log plots)
//! and the win/loss + total-time comparisons quoted in the text,
//! including `LB_ENHANCED*` (best k per dataset, k ≤ 16).
//!
//! ```sh
//! cargo bench --bench fig_nn_search
//! DTWB_TAKE=10 DTWB_REPEATS=2 cargo bench --bench fig_nn_search   # quick pass
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec};
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::nn_timing::{
    nn_timing, scatter_table, win_loss_ratio, TimedBound,
};
use dtw_bounds::experiments::with_recommended_window;
use dtw_bounds::metrics::format_duration;
use dtw_bounds::search::SearchStrategy;

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let archive = generate_archive(&ArchiveSpec::new(knobs.scale, knobs.seed));
    let datasets = with_recommended_window(&archive);
    let take = knobs.take_of(datasets.len(), usize::MAX);
    let datasets = &datasets[..take];
    let windows: Vec<usize> = datasets.iter().map(|d| d.window).collect();

    let bounds = [
        TimedBound::Fixed(BoundKind::Keogh),     // 0
        TimedBound::Fixed(BoundKind::Improved),  // 1
        TimedBound::Fixed(BoundKind::Petitjean), // 2
        TimedBound::Fixed(BoundKind::Webb),      // 3
        TimedBound::EnhancedStar,                // 4
    ];

    for (mode, figs) in [
        (SearchStrategy::RandomOrder, "Figures 19, 20, 23, 24, 28"),
        (SearchStrategy::Sorted, "Figures 21, 22, 25, 26, 27"),
    ] {
        benchkit::banner(&format!(
            "NN search, {mode}, {} datasets, {} repeats — {figs}",
            datasets.len(),
            knobs.repeats
        ));
        let cols =
            nn_timing::<Squared>(datasets, &windows, &bounds, mode, knobs.repeats, knobs.seed);
        for c in &cols {
            println!("{:<16} total {}", c.label, format_duration(c.total()));
        }
        for (a, b, fig) in [
            (3usize, 0usize, "Webb vs Keogh    "),
            (3, 1, "Webb vs Improved "),
            (2, 0, "Petitjean vs Keogh"),
            (2, 1, "Petitjean vs Improved"),
            (3, 4, "Webb vs Enhanced*"),
        ] {
            let (w, l, r) = win_loss_ratio(&cols[a], &cols[b]);
            println!("  {fig}: {w}/{l} wins, total ratio {r:.2}");
        }
        // Scatter data for the headline figure of each mode.
        println!("\nscatter (Webb vs Keogh):");
        println!("{}", scatter_table(&cols[3], &cols[0]).to_csv());
    }
}
