//! Microbenchmark: per-pair cost of every bound vs series length and
//! window — the efficiency half of the paper's trade-off, isolated from
//! search effects. Also the workhorse of the §Perf iteration log.
//!
//! ```sh
//! cargo bench --bench bound_micro
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::dtw::dtw;
use dtw_bounds::metrics::Table;

fn main() {
    let mut rng = Rng::seeded(0xBEEF);
    let mut scratch = Scratch::default();

    benchkit::banner("Per-pair bound cost (ns), squared delta");
    let mut table = Table::new(vec!["bound", "l=64 w=6", "l=256 w=26", "l=1024 w=102", "l=1024 w=205"]);

    let configs: Vec<(usize, usize)> = vec![(64, 6), (256, 26), (1024, 102), (1024, 205)];
    let pairs: Vec<(PreparedSeries, PreparedSeries, usize)> = configs
        .iter()
        .map(|&(l, w)| {
            let a: Vec<f64> = (0..l).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..l).map(|_| rng.normal()).collect();
            (PreparedSeries::prepare(a, w), PreparedSeries::prepare(b, w), w)
        })
        .collect();

    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for &bound in BoundKind::ALL {
        let mut cells = Vec::new();
        for (q, t, w) in &pairs {
            let iters = (2_000_000 / q.len()).max(100);
            let ns = benchkit::ns_per_call(iters, || {
                bound.compute::<Squared>(q, t, *w, f64::INFINITY, &mut scratch)
            });
            cells.push(ns);
        }
        rows.push((bound.name(), cells));
    }
    // DTW itself for perspective.
    for (q, t, w) in &pairs {
        let iters = (200_000 / (q.len() * (*w + 1)).max(1)).max(10);
        let ns = benchkit::ns_per_call(iters, || dtw::<Squared>(&q.values, &t.values, *w));
        if let Some(last) = rows.last() {
            let _ = last;
        }
        rows.push((format!("(full DTW l={} w={})", q.len(), w), vec![ns]));
    }

    for (name, cells) in &rows {
        let mut row = vec![name.clone()];
        for i in 0..4 {
            row.push(cells.get(i).map(|v| format!("{v:.0}")).unwrap_or_default());
        }
        table.row(row);
    }
    println!("{}", table.to_markdown());

    // Headline efficiency claims, asserted on this machine:
    let get = |name: &str, col: usize| -> f64 {
        rows.iter().find(|(n, _)| n == name).map(|(_, c)| c[col]).unwrap()
    };
    for col in 0..4 {
        let webb = get("LB_Webb", col);
        let improved = get("LB_Improved", col);
        let petitjean = get("LB_Petitjean", col);
        println!(
            "l/w config {col}: Webb {webb:.0}ns vs Improved {improved:.0}ns ({:.2}x) vs Petitjean {petitjean:.0}ns",
            improved / webb
        );
    }
}
