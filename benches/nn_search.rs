//! NN-search serving benchmark over the `DtwIndex` facade: queries/sec
//! and prune rate per search strategy (and the brute-force baseline),
//! plus a machine-readable `BENCH_nn_search.json` so the search-path
//! perf trajectory is tracked across PRs alongside
//! `BENCH_runtime_batch.json`.
//!
//! ```sh
//! cargo bench --bench nn_search
//! DTWB_SCALE=tiny DTWB_REPEATS=1 cargo bench --bench nn_search   # quick pass
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use std::time::Instant;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec};
use dtw_bounds::data::Dataset;
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::with_recommended_window;
use dtw_bounds::index::{DtwIndex, QueryOptions};
use dtw_bounds::metrics::Table;
use dtw_bounds::search::nn::SearchStats;
use dtw_bounds::search::SearchStrategy;

/// (strategy, bound) cells to compare. Brute force is the baseline.
fn cells() -> Vec<(SearchStrategy, BoundKind)> {
    vec![
        (SearchStrategy::BruteForce, BoundKind::Webb), // bound unused
        (SearchStrategy::RandomOrder, BoundKind::Petitjean),
        (SearchStrategy::RandomOrder, BoundKind::Webb),
        (SearchStrategy::Sorted, BoundKind::Keogh),
        (SearchStrategy::Sorted, BoundKind::Webb),
        (SearchStrategy::SortedPrecomputed, BoundKind::Keogh),
    ]
}

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let archive = generate_archive(&ArchiveSpec::new(knobs.scale, knobs.seed));
    let datasets: Vec<&Dataset> = with_recommended_window(&archive);
    let take = knobs.take_of(datasets.len(), 6);
    let datasets = &datasets[..take];

    benchkit::banner(&format!(
        "NN search via DtwIndex: {} datasets, {} repeats, k=1",
        datasets.len(),
        knobs.repeats
    ));

    let mut table = Table::new(vec!["strategy", "bound", "queries/s", "prune rate"]);
    let mut records = Vec::new();

    for (strategy, bound) in cells() {
        let bound_name =
            if strategy == SearchStrategy::BruteForce { "none".to_string() } else { bound.name() };
        let mut total_queries = 0usize;
        let mut total_secs = 0.0f64;
        let mut stats = SearchStats::default();
        let mut pairs = 0usize;

        for ds in datasets {
            let index = DtwIndex::builder_from_dataset(ds)
                .bound(bound)
                .strategy(strategy)
                .build()
                .expect("dataset series share one length");
            let mut searcher = index.searcher();
            let queries: Vec<Vec<f64>> =
                ds.test.iter().map(|s| s.values.clone()).collect();
            // Warmup pass, then timed repeats.
            let run = |searcher: &mut dtw_bounds::index::Searcher| {
                if strategy == SearchStrategy::SortedPrecomputed {
                    searcher.query_batch::<Squared>(&queries, &QueryOptions::default())
                } else {
                    queries
                        .iter()
                        .map(|q| {
                            searcher.query_values::<Squared>(q, &QueryOptions::default())
                        })
                        .collect()
                }
            };
            run(&mut searcher);
            for _ in 0..knobs.repeats {
                let t0 = Instant::now();
                let outs = run(&mut searcher);
                total_secs += t0.elapsed().as_secs_f64();
                total_queries += outs.len();
                for o in &outs {
                    stats.add(&o.stats);
                }
                pairs += queries.len() * index.len();
            }
        }

        let qps = total_queries as f64 / total_secs;
        let prune_rate = stats.pruned as f64 / pairs.max(1) as f64;
        table.row(vec![
            strategy.name().to_string(),
            bound_name.clone(),
            format!("{qps:.0}"),
            format!("{:.1}%", prune_rate * 100.0),
        ]);
        records.push(benchkit::NnSearchRecord {
            strategy: strategy.name().to_string(),
            bound: bound_name,
            datasets: datasets.len(),
            queries: total_queries,
            queries_per_sec: qps,
            prune_rate,
        });
    }

    println!("{}", table.to_markdown());
    println!("(prune rate counts candidates rejected by the bound alone; batched cells");
    println!(" additionally early-abandon inside the prefilter, which shows in queries/s)");
    benchkit::write_nn_search_json("BENCH_nn_search.json", &records)
        .expect("write BENCH_nn_search.json");
    println!("wrote BENCH_nn_search.json ({} records)", records.len());
}
