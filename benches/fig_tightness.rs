//! Figures 1, 2 and 15–18 (§6.1): per-dataset tightness of the new bounds
//! against the baselines, at the archive's recommended windows.
//!
//! Emits the per-dataset tightness matrix (CSV — each pairwise scatter of
//! the paper's figures is two of its columns) plus the win/loss counts
//! the §6.1 text quotes.
//!
//! ```sh
//! cargo bench --bench fig_tightness            # small archive
//! DTWB_SCALE=tiny cargo bench --bench fig_tightness
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec};
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::{tightness_experiment, with_recommended_window};

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let archive = generate_archive(&ArchiveSpec::new(knobs.scale, knobs.seed));
    let datasets = with_recommended_window(&archive);
    let take = knobs.take_of(datasets.len(), usize::MAX);
    let datasets = &datasets[..take];
    benchkit::banner(&format!(
        "Tightness at recommended windows — {} datasets (Figures 1, 2, 15-18)",
        datasets.len()
    ));

    let bounds = vec![
        BoundKind::Keogh,
        BoundKind::Improved,
        BoundKind::Enhanced(8),
        BoundKind::Petitjean,
        BoundKind::Webb,
        BoundKind::WebbNoLr,
    ];
    let res = tightness_experiment::<Squared>(datasets, &bounds);
    println!("{}", res.to_table().to_csv());

    let quote = |fig: &str, a: BoundKind, b: BoundKind| {
        let (w, l) = res.win_loss(a, b);
        let mean = |k: BoundKind| {
            let c = res.col(k).unwrap();
            res.rows.iter().map(|(_, _, t)| t[c]).sum::<f64>() / res.rows.len() as f64
        };
        println!(
            "{fig}: {a} vs {b}: tighter on {w}, less tight on {l} (means {:.4} vs {:.4})",
            mean(a),
            mean(b)
        );
    };
    quote("Fig 1 ", BoundKind::Webb, BoundKind::Keogh);
    quote("Fig 2 ", BoundKind::Webb, BoundKind::Improved);
    quote("Fig 15", BoundKind::Petitjean, BoundKind::Keogh);
    quote("Fig 16", BoundKind::Petitjean, BoundKind::Improved);
    quote("Fig 17", BoundKind::Petitjean, BoundKind::Enhanced(8));
    quote("Fig 18", BoundKind::Webb, BoundKind::Enhanced(8));

    // Paper's §6.1 expectations, as hard checks on this run:
    let (_, petitjean_losses) = res.win_loss(BoundKind::Petitjean, BoundKind::Improved);
    assert_eq!(
        petitjean_losses, 0,
        "LB_Petitjean should never be less tight than LB_Improved on dataset means"
    );
    let (_, webb_losses_keogh) = res.win_loss(BoundKind::Webb, BoundKind::Keogh);
    assert_eq!(webb_losses_keogh, 0, "LB_Webb should never lose to LB_Keogh on dataset means");
}
