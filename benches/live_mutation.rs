//! Live-mutation trajectory bench: the three costs of the mutable index
//! (`rust/src/live/`, ARCHITECTURE.md "Live mutation & generations").
//!
//! 1. **Inserts/sec** — the delta-shard write path (z-norm policy +
//!    envelope preparation + append; no rebuild).
//! 2. **Query latency vs delta fill** — k-NN queries/sec as pending
//!    inserts accumulate in the un-compacted delta shard (fill 0 is the
//!    frozen baseline). Each sweep point first asserts the live answers
//!    are bit-equal to a cold rebuild over the same logical series — the
//!    subsystem's defining contract — before timing.
//! 3. **Compaction wall time** — one `compact()` folding base + delta −
//!    tombstones into the next generation, at 1/2/4 builder threads.
//!
//! Records land in `BENCH_live_mutation.json` (`inserts`, `delta_query`,
//! `compaction` arrays).
//!
//! Knobs (env): `DTWB_REPEATS` (default 3), `DTWB_SERIES_LEN` (128),
//! `DTWB_CANDIDATES` (2000), `DTWB_QUERIES` (16), `DTWB_SHARDS` (2),
//! `DTWB_INSERTS` (256, the write-path batch).
//!
//! ```sh
//! cargo bench --bench live_mutation
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use std::time::Instant;

use dtw_bounds::coordinator::NnEngine;
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::index::{DtwIndex, QueryOptions};
use dtw_bounds::metrics::{Summary, Table};

/// Smooth random-walk series around a per-family offset (the same pool
/// shape as `cluster_prune`): inserts drawn from the same families as
/// the base keep the delta scan honest — its candidates are competitive,
/// not instantly pruned.
fn family_walk(rng: &mut Rng, l: usize, offset: f64) -> Vec<f64> {
    let mut v = offset;
    (0..l)
        .map(|_| {
            v += rng.normal() * 0.25;
            v
        })
        .collect()
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn build(
    values: Vec<Vec<f64>>,
    labels: Vec<u32>,
    w: usize,
    shards: usize,
    threads: usize,
) -> DtwIndex {
    DtwIndex::builder(values)
        .labels(labels)
        .window(w)
        .shards(shards)
        .threads(threads)
        .build()
        .expect("one shared length")
}

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let l = env_usize("DTWB_SERIES_LEN", 128);
    let n = env_usize("DTWB_CANDIDATES", 2_000);
    let nq = env_usize("DTWB_QUERIES", 16);
    let shards = env_usize("DTWB_SHARDS", 2).max(1);
    let batch = env_usize("DTWB_INSERTS", 256).max(1);
    let w = (l / 10).max(1);
    let families = 12usize;
    let mut rng = Rng::seeded(0x11FE);

    let train: Vec<Vec<f64>> =
        (0..n).map(|i| family_walk(&mut rng, l, 6.0 * (i % families) as f64)).collect();
    let labels: Vec<u32> = (0..n).map(|i| (i % families) as u32).collect();
    let donors: Vec<(u32, Vec<f64>)> = (0..batch)
        .map(|j| (1000 + j as u32, family_walk(&mut rng, l, 6.0 * (j % families) as f64)))
        .collect();
    let queries: Vec<Vec<f64>> =
        (0..nq).map(|i| family_walk(&mut rng, l, 6.0 * (i % families) as f64)).collect();
    let opts = QueryOptions::k(3);

    benchkit::banner(&format!(
        "Live mutation (n={n}, l={l}, w={w}, k=3, shards={shards}, \
         insert batch={batch})"
    ));

    let base = build(train.clone(), labels.clone(), w, shards, 2);
    let mut engine = NnEngine::from_index(base.clone());

    // 1. Write path: inserts/sec into the delta shard. `replace_index`
    //    clears the live state between repeats, so every repeat appends
    //    the same batch to an empty delta.
    let mut insert_times = Vec::new();
    for rep in 0..=knobs.repeats {
        engine.replace_index(base.clone());
        let t0 = Instant::now();
        for (label, values) in &donors {
            engine.insert(*label, values.clone()).expect("insert");
        }
        let dt = t0.elapsed().as_secs_f64();
        if rep > 0 {
            insert_times.push(dt);
        }
    }
    let inserts_per_sec = batch as f64 / Summary::of(&insert_times).mean;
    println!("write path: {inserts_per_sec:.0} inserts/s (batch {batch})");
    let insert_records = vec![benchkit::LiveInsertRecord {
        batch,
        series_len: l,
        inserts_per_sec,
    }];

    // 2. Read path: k-NN latency as the delta fills.
    let mut table = Table::new(vec!["delta fill", "queries/s", "us/query", "vs frozen"]);
    let mut query_records: Vec<benchkit::DeltaQueryRecord> = Vec::new();
    let mut base_qps = 0.0f64;
    for &fill in &[0usize, 8, 32, 128] {
        let fill = fill.min(batch);
        engine.replace_index(base.clone());
        for (label, values) in donors.iter().take(fill) {
            engine.insert(*label, values.clone()).expect("insert");
        }

        // Exactness spot check before timing: live answers must be
        // bit-equal to a cold rebuild over base + the inserted series.
        let mut cold_values = train.clone();
        let mut cold_labels = labels.clone();
        for (label, values) in donors.iter().take(fill) {
            cold_values.push(values.clone());
            cold_labels.push(*label);
        }
        let cold = build(cold_values, cold_labels, w, shards, 2);
        let mut cold_searcher = cold.searcher();
        for q in &queries {
            let live: Vec<(usize, u32, f64)> = engine
                .query_with(q, &opts)
                .neighbors
                .iter()
                .map(|nb| (nb.index, nb.label, nb.distance))
                .collect();
            let frozen: Vec<(usize, u32, f64)> = cold_searcher
                .query_values::<Squared>(q, &opts)
                .neighbors
                .iter()
                .map(|nb| (nb.index, nb.label, nb.distance))
                .collect();
            assert_eq!(live, frozen, "live search must be bit-equal to a cold rebuild");
        }

        let mean = Summary::of(&benchkit::time_reps(knobs.repeats, || {
            let mut acc = 0usize;
            for q in &queries {
                acc += engine.query_with(q, &opts).neighbors.len();
            }
            std::hint::black_box(acc);
        }))
        .mean;
        let qps = nq as f64 / mean;
        let us = 1e6 * mean / nq as f64;
        if fill == 0 {
            base_qps = qps;
        }
        table.row(vec![
            fill.to_string(),
            format!("{qps:.1}"),
            format!("{us:.1}"),
            format!("{:.2}x", qps / base_qps),
        ]);
        query_records.push(benchkit::DeltaQueryRecord {
            delta_fill: fill,
            candidates: n,
            queries: nq,
            queries_per_sec: qps,
            micros_per_query: us,
        });
    }
    println!("{}", table.to_markdown());

    // 3. Compaction: fold a fixed mutation load into the next
    //    generation, per builder thread count. Deleting logical id 0
    //    repeatedly tombstones a fresh base series each time (ids shift
    //    down as a rebuild would number them).
    let fill = 64.min(batch);
    let tombs = 16.min(n / 2);
    let mut compact_table = Table::new(vec!["threads", "series", "compaction ms"]);
    let mut compact_records: Vec<benchkit::CompactionRecord> = Vec::new();
    for &threads in &[1usize, 2, 4] {
        let base_t = build(train.clone(), labels.clone(), w, shards, threads);
        let mut engine = NnEngine::from_index(base_t.clone());
        let mut times = Vec::new();
        let mut series = 0usize;
        for rep in 0..=knobs.repeats {
            engine.replace_index(base_t.clone());
            for (label, values) in donors.iter().take(fill) {
                engine.insert(*label, values.clone()).expect("insert");
            }
            for _ in 0..tombs {
                engine.delete(0).expect("delete");
            }
            series = engine.logical_len();
            let t0 = Instant::now();
            engine.compact().expect("compact");
            let dt = t0.elapsed().as_secs_f64();
            if rep > 0 {
                times.push(dt);
            }
        }
        let millis = 1e3 * Summary::of(&times).mean;
        compact_table.row(vec![
            threads.to_string(),
            series.to_string(),
            format!("{millis:.1}"),
        ]);
        compact_records.push(benchkit::CompactionRecord {
            threads,
            series,
            delta_fill: fill,
            tombstones: tombs,
            millis,
        });
    }
    println!("{}", compact_table.to_markdown());

    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the trajectory file at the workspace root regardless.
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_live_mutation.json");
    benchkit::write_live_mutation_json(out_path, &insert_records, &query_records, &compact_records)
        .expect("write BENCH_live_mutation.json");
    println!(
        "wrote BENCH_live_mutation.json ({} insert, {} query, {} compaction records)",
        insert_records.len(),
        query_records.len(),
        compact_records.len()
    );
}
