//! Shared harness for the `harness = false` figure drivers (no criterion
//! in the offline build — DESIGN.md §5). Provides env-tunable workload
//! knobs and simple warmup+repeat timers.
//!
//! Scenario benchmarking, machine-readable perf reporting and the
//! regression gate all live in the `dtw-bench` crate now (see
//! docs/benchmarks.md); the drivers that remain here exist to print the
//! paper's figures and tables, not to track performance.

#![allow(dead_code)]

use std::time::Instant;

use dtw_bounds::data::synthetic::Scale;

/// Workload knobs, from environment variables so `cargo bench` stays
/// argument-free:
/// * `DTWB_SCALE`  — tiny | small | paper (default small)
/// * `DTWB_TAKE`   — max datasets per experiment (default experiment-specific)
/// * `DTWB_REPEATS`— timing repeats (default 3; paper uses 10)
/// * `DTWB_SEED`   — archive seed (default 2021)
pub struct Knobs {
    pub scale: Scale,
    pub take: Option<usize>,
    pub repeats: usize,
    pub seed: u64,
}

impl Knobs {
    pub fn from_env() -> Knobs {
        let scale = std::env::var("DTWB_SCALE")
            .ok()
            .and_then(|s| Scale::parse(&s))
            .unwrap_or(Scale::Small);
        let take = std::env::var("DTWB_TAKE").ok().and_then(|s| s.parse().ok());
        let repeats = std::env::var("DTWB_REPEATS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        let seed = std::env::var("DTWB_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(2021);
        Knobs { scale, take, repeats, seed }
    }

    pub fn take_of(&self, available: usize, default_cap: usize) -> usize {
        self.take.unwrap_or(default_cap).min(available)
    }
}

/// Time `f` (warmup once, then `reps` measured runs); returns per-run
/// seconds.
pub fn time_reps<F: FnMut()>(reps: usize, mut f: F) -> Vec<f64> {
    f(); // warmup
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Nanoseconds per call over `iters` iterations of `f` (with warmup),
/// using a black-box accumulator to defeat dead-code elimination.
pub fn ns_per_call<F: FnMut() -> f64>(iters: usize, mut f: F) -> f64 {
    let mut acc = 0.0;
    for _ in 0..iters.min(100) {
        acc += f(); // warmup
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        acc += f();
    }
    let dt = t0.elapsed().as_nanos() as f64 / iters as f64;
    std::hint::black_box(acc);
    dt
}

/// Print a section banner.
pub fn banner(title: &str) {
    println!("\n{}\n{}", title, "=".repeat(title.len()));
}
