//! Shared harness for the `harness = false` benches (no criterion in the
//! offline build — DESIGN.md §5). Provides env-tunable workload knobs and
//! a warmup+repeat timer with mean/std reporting.

#![allow(dead_code)]

use std::time::Instant;

use dtw_bounds::data::synthetic::Scale;

/// Workload knobs, from environment variables so `cargo bench` stays
/// argument-free:
/// * `DTWB_SCALE`  — tiny | small | paper (default small)
/// * `DTWB_TAKE`   — max datasets per experiment (default experiment-specific)
/// * `DTWB_REPEATS`— timing repeats (default 3; paper uses 10)
/// * `DTWB_SEED`   — archive seed (default 2021)
pub struct Knobs {
    pub scale: Scale,
    pub take: Option<usize>,
    pub repeats: usize,
    pub seed: u64,
}

impl Knobs {
    pub fn from_env() -> Knobs {
        let scale = std::env::var("DTWB_SCALE")
            .ok()
            .and_then(|s| Scale::parse(&s))
            .unwrap_or(Scale::Small);
        let take = std::env::var("DTWB_TAKE").ok().and_then(|s| s.parse().ok());
        let repeats = std::env::var("DTWB_REPEATS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        let seed = std::env::var("DTWB_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(2021);
        Knobs { scale, take, repeats, seed }
    }

    pub fn take_of(&self, available: usize, default_cap: usize) -> usize {
        self.take.unwrap_or(default_cap).min(available)
    }
}

/// Time `f` (warmup once, then `reps` measured runs); returns per-run
/// seconds.
pub fn time_reps<F: FnMut()>(reps: usize, mut f: F) -> Vec<f64> {
    f(); // warmup
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Nanoseconds per call over `iters` iterations of `f` (with warmup),
/// using a black-box accumulator to defeat dead-code elimination.
pub fn ns_per_call<F: FnMut() -> f64>(iters: usize, mut f: F) -> f64 {
    let mut acc = 0.0;
    for _ in 0..iters.min(100) {
        acc += f(); // warmup
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        acc += f();
    }
    let dt = t0.elapsed().as_nanos() as f64 / iters as f64;
    std::hint::black_box(acc);
    dt
}

/// Print a section banner.
pub fn banner(title: &str) {
    println!("\n{}\n{}", title, "=".repeat(title.len()));
}

/// One machine-readable benchmark record for the perf-trajectory files
/// (`BENCH_*.json`): which bound/kernel, at which workload shape, at what
/// cost per bound evaluation.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Bound / kernel name, e.g. `lb_keogh/native`.
    pub bound: String,
    /// Series length ℓ.
    pub series_len: usize,
    /// Candidates scored per query.
    pub candidates: usize,
    /// Nanoseconds per bound evaluation (one query × candidate pair).
    pub ns_per_op: f64,
}

/// One machine-readable record for the NN-search trajectory file
/// (`BENCH_nn_search.json`): throughput and prune rate per (strategy,
/// bound) over a workload of full test-set queries.
#[derive(Debug, Clone)]
pub struct NnSearchRecord {
    /// Search strategy name, e.g. `sorted`, `sorted-precomputed`.
    pub strategy: String,
    /// Screening bound name (`none` for brute force).
    pub bound: String,
    /// Datasets aggregated.
    pub datasets: usize,
    /// Total queries answered.
    pub queries: usize,
    /// Queries per second across the workload.
    pub queries_per_sec: f64,
    /// Fraction of query-candidate pairs pruned by the bound alone.
    pub prune_rate: f64,
}

/// Write NN-search records as a JSON array (manual formatting — no
/// `serde` in the offline build; stable for line-diffing across PRs).
pub fn write_nn_search_json(path: &str, records: &[NnSearchRecord]) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let sep = if i + 1 == records.len() { "" } else { "," };
        out.push_str(&format!(
            "  {{\"strategy\": \"{}\", \"bound\": \"{}\", \"datasets\": {}, \
             \"queries\": {}, \"queries_per_sec\": {:.1}, \"prune_rate\": {:.4}}}{sep}\n",
            r.strategy.replace('\\', "\\\\").replace('"', "\\\""),
            r.bound.replace('\\', "\\\\").replace('"', "\\\""),
            r.datasets,
            r.queries,
            r.queries_per_sec,
            r.prune_rate,
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

/// One machine-readable record for the streaming-search trajectory file
/// (`BENCH_stream_search.json`): throughput and per-cascade-stage prune
/// rate over a synthetic monitor workload.
#[derive(Debug, Clone)]
pub struct StreamSearchRecord {
    /// Cascade label, e.g. `LB_KimFL->LB_Keogh->LB_Webb`.
    pub cascade: String,
    /// Stream samples scanned (per repeat).
    pub samples: usize,
    /// Windows evaluated (per repeat).
    pub windows: usize,
    /// Windows matched (per repeat).
    pub matches: usize,
    /// Stream samples per second of search-busy time.
    pub samples_per_sec: f64,
    /// Fraction of window × candidate pairs pruned by the whole cascade.
    pub prune_rate: f64,
    /// Per-stage `(bound name, fraction of pairs pruned at this stage)`.
    pub stage_prune: Vec<(String, f64)>,
    /// Full DTW computations started (per repeat).
    pub dtw_calls: usize,
}

/// Write streaming-search records as a JSON array (manual formatting —
/// no `serde` in the offline build; stable for line-diffing across PRs).
pub fn write_stream_search_json(
    path: &str,
    records: &[StreamSearchRecord],
) -> std::io::Result<()> {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let sep = if i + 1 == records.len() { "" } else { "," };
        let stages: Vec<String> = r
            .stage_prune
            .iter()
            .map(|(name, rate)| format!("\"{}\": {rate:.4}", esc(name)))
            .collect();
        out.push_str(&format!(
            "  {{\"cascade\": \"{}\", \"samples\": {}, \"windows\": {}, \
             \"matches\": {}, \"samples_per_sec\": {:.1}, \"prune_rate\": {:.4}, \
             \"stages\": {{{}}}, \"dtw_calls\": {}}}{sep}\n",
            esc(&r.cascade),
            r.samples,
            r.windows,
            r.matches,
            r.samples_per_sec,
            r.prune_rate,
            stages.join(", "),
            r.dtw_calls,
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

/// One machine-readable record for the exact-DTW kernel trajectory file
/// (`BENCH_dtw_kernel.json`, `"kernels"` array): DP-cell throughput of
/// one kernel variant on the windowed NN workload.
#[derive(Debug, Clone)]
pub struct DtwKernelRecord {
    /// Kernel variant: `scalar` (`dtw_ea`), `pruned` (`dtw_ea_pruned`),
    /// `pruned+cascade` (pruned with the `LB_KEOGH` tail).
    pub kernel: String,
    /// Series length ℓ.
    pub series_len: usize,
    /// Sakoe–Chiba half-window w.
    pub window: usize,
    /// Banded DP cells evaluated per second (band cells of every call,
    /// abandoned or not — so pruning shows up as *higher* cells/sec).
    pub cells_per_sec: f64,
}

/// One machine-readable record for the thread-scaling half of
/// `BENCH_dtw_kernel.json` (`"threads"` array): k-NN queries/sec at a
/// fixed workload as the search executor widens.
#[derive(Debug, Clone)]
pub struct ThreadScalingRecord {
    /// Worker thread count.
    pub threads: usize,
    /// Queries answered per measured repeat.
    pub queries: usize,
    /// Queries per second.
    pub queries_per_sec: f64,
}

/// One machine-readable record for the per-bound screening half of
/// `BENCH_dtw_kernel.json` (`"bounds"` array): envelope cells scanned
/// per second by one `BoundKind` screen — the source of the cells/sec
/// column on `BoundKind`'s tightness-vs-cost table.
#[derive(Debug, Clone)]
pub struct BoundScreenRecord {
    /// Canonical bound name, e.g. `LB_Webb`.
    pub bound: String,
    /// Series length ℓ (= cells credited per screen evaluation).
    pub series_len: usize,
    /// Screen cells per second (ℓ / seconds-per-evaluation).
    pub cells_per_sec: f64,
}

/// Write the exact-DTW kernel trajectory file: one JSON object with
/// `kernels`, `threads` and `bounds` arrays (manual formatting — no
/// `serde` in the offline build; stable for line-diffing across PRs).
/// `benches/check_regression.rs` parses exactly this shape.
pub fn write_dtw_kernel_json(
    path: &str,
    kernels: &[DtwKernelRecord],
    threads: &[ThreadScalingRecord],
    bounds: &[BoundScreenRecord],
) -> std::io::Result<()> {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let mut out = String::from("{\n  \"kernels\": [\n");
    for (i, r) in kernels.iter().enumerate() {
        let sep = if i + 1 == kernels.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"series_len\": {}, \"window\": {}, \
             \"cells_per_sec\": {:.1}}}{sep}\n",
            esc(&r.kernel),
            r.series_len,
            r.window,
            r.cells_per_sec,
        ));
    }
    out.push_str("  ],\n  \"threads\": [\n");
    for (i, r) in threads.iter().enumerate() {
        let sep = if i + 1 == threads.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"threads\": {}, \"queries\": {}, \"queries_per_sec\": {:.1}}}{sep}\n",
            r.threads, r.queries, r.queries_per_sec,
        ));
    }
    out.push_str("  ],\n  \"bounds\": [\n");
    for (i, r) in bounds.iter().enumerate() {
        let sep = if i + 1 == bounds.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"bound\": \"{}\", \"series_len\": {}, \"cells_per_sec\": {:.1}}}{sep}\n",
            esc(&r.bound),
            r.series_len,
            r.cells_per_sec,
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// One machine-readable record for the persistence half of
/// `BENCH_index_persist.json`: how long the cold-start path takes,
/// versus rebuilding the same index from raw series.
#[derive(Debug, Clone)]
pub struct ColdStartRecord {
    /// `load` (snapshot → ready index) or `rebuild` (raw series →
    /// ready index, the no-snapshot baseline).
    pub phase: String,
    /// Indexed series count.
    pub series: usize,
    /// Series length ℓ.
    pub series_len: usize,
    /// Shard count of the index.
    pub shards: usize,
    /// Snapshot size in bytes (0 for the rebuild baseline).
    pub bytes: u64,
    /// Milliseconds to a ready-to-serve index.
    pub millis: f64,
}

/// One machine-readable record for the sharded-search half of
/// `BENCH_index_persist.json`: k-NN throughput per shard count.
#[derive(Debug, Clone)]
pub struct ShardScalingRecord {
    /// Shard count.
    pub shards: usize,
    /// Worker thread count.
    pub threads: usize,
    /// Queries answered per measured repeat.
    pub queries: usize,
    /// Queries per second.
    pub queries_per_sec: f64,
}

/// Write the persistence/sharding trajectory file: one JSON object with
/// `cold_start` and `shard_scaling` arrays (manual formatting — no
/// `serde` in the offline build; stable for line-diffing across PRs).
pub fn write_index_persist_json(
    path: &str,
    cold: &[ColdStartRecord],
    scaling: &[ShardScalingRecord],
) -> std::io::Result<()> {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let mut out = String::from("{\n  \"cold_start\": [\n");
    for (i, r) in cold.iter().enumerate() {
        let sep = if i + 1 == cold.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"phase\": \"{}\", \"series\": {}, \"series_len\": {}, \
             \"shards\": {}, \"bytes\": {}, \"millis\": {:.3}}}{sep}\n",
            esc(&r.phase),
            r.series,
            r.series_len,
            r.shards,
            r.bytes,
            r.millis,
        ));
    }
    out.push_str("  ],\n  \"shard_scaling\": [\n");
    for (i, r) in scaling.iter().enumerate() {
        let sep = if i + 1 == scaling.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"shards\": {}, \"threads\": {}, \"queries\": {}, \
             \"queries_per_sec\": {:.1}}}{sep}\n",
            r.shards, r.threads, r.queries, r.queries_per_sec,
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// One machine-readable record for the cluster-pruning trajectory file
/// (`BENCH_cluster_prune.json`): k-NN throughput and cluster-level prune
/// rate at one cluster count over a synthetic candidate pool.
/// `clusters = 0` is the flat baseline (no cluster layer).
#[derive(Debug, Clone)]
pub struct ClusterPruneRecord {
    /// Per-shard cluster count the index was built with (0 = flat).
    pub clusters: usize,
    /// Shard count of the index.
    pub shards: usize,
    /// Worker thread count.
    pub threads: usize,
    /// Candidate series in the index.
    pub candidates: usize,
    /// Queries answered per measured repeat.
    pub queries: usize,
    /// Queries per second.
    pub queries_per_sec: f64,
    /// Fraction of query × candidate pairs skipped by cluster-level
    /// bounds alone (members of skipped clusters / total pairs).
    pub cluster_prune_rate: f64,
    /// Cluster-level merged-envelope bound evaluations (total over the
    /// query set).
    pub cluster_lb_calls: usize,
    /// Whole clusters skipped (total over the query set).
    pub clusters_pruned: usize,
}

/// Write cluster-pruning records as a JSON array (manual formatting —
/// no `serde` in the offline build; stable for line-diffing across PRs).
pub fn write_cluster_prune_json(
    path: &str,
    records: &[ClusterPruneRecord],
) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let sep = if i + 1 == records.len() { "" } else { "," };
        out.push_str(&format!(
            "  {{\"clusters\": {}, \"shards\": {}, \"threads\": {}, \
             \"candidates\": {}, \"queries\": {}, \"queries_per_sec\": {:.1}, \
             \"cluster_prune_rate\": {:.4}, \"cluster_lb_calls\": {}, \
             \"clusters_pruned\": {}}}{sep}\n",
            r.clusters,
            r.shards,
            r.threads,
            r.candidates,
            r.queries,
            r.queries_per_sec,
            r.cluster_prune_rate,
            r.cluster_lb_calls,
            r.clusters_pruned,
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

/// One machine-readable record for the write-path half of
/// `BENCH_live_mutation.json` (`"inserts"` array): how fast series land
/// in the delta shard (envelope prep + append, no rebuild).
#[derive(Debug, Clone)]
pub struct LiveInsertRecord {
    /// Series inserted per measured repeat.
    pub batch: usize,
    /// Series length ℓ.
    pub series_len: usize,
    /// Inserts per second.
    pub inserts_per_sec: f64,
}

/// One machine-readable record for the read-path half of
/// `BENCH_live_mutation.json` (`"delta_query"` array): k-NN latency as
/// the un-compacted delta shard fills (fill 0 = the frozen baseline).
#[derive(Debug, Clone)]
pub struct DeltaQueryRecord {
    /// Pending delta-shard inserts during the measurement.
    pub delta_fill: usize,
    /// Frozen base candidates.
    pub candidates: usize,
    /// Queries answered per measured repeat.
    pub queries: usize,
    /// Queries per second.
    pub queries_per_sec: f64,
    /// Mean microseconds per query.
    pub micros_per_query: f64,
}

/// One machine-readable record for the fold half of
/// `BENCH_live_mutation.json` (`"compaction"` array): wall time of one
/// `compact()` — the full rebuild of base + delta − tombstones into the
/// next generation — per builder thread count.
#[derive(Debug, Clone)]
pub struct CompactionRecord {
    /// Builder/search thread count of the index being compacted.
    pub threads: usize,
    /// Logical series folded into the new generation.
    pub series: usize,
    /// Pending delta inserts folded in.
    pub delta_fill: usize,
    /// Pending base tombstones folded out.
    pub tombstones: usize,
    /// Milliseconds per compaction.
    pub millis: f64,
}

/// Write the live-mutation trajectory file: one JSON object with
/// `inserts`, `delta_query` and `compaction` arrays (manual formatting —
/// no `serde` in the offline build; stable for line-diffing across PRs).
pub fn write_live_mutation_json(
    path: &str,
    inserts: &[LiveInsertRecord],
    delta_query: &[DeltaQueryRecord],
    compaction: &[CompactionRecord],
) -> std::io::Result<()> {
    let mut out = String::from("{\n  \"inserts\": [\n");
    for (i, r) in inserts.iter().enumerate() {
        let sep = if i + 1 == inserts.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"batch\": {}, \"series_len\": {}, \"inserts_per_sec\": {:.1}}}{sep}\n",
            r.batch, r.series_len, r.inserts_per_sec,
        ));
    }
    out.push_str("  ],\n  \"delta_query\": [\n");
    for (i, r) in delta_query.iter().enumerate() {
        let sep = if i + 1 == delta_query.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"delta_fill\": {}, \"candidates\": {}, \"queries\": {}, \
             \"queries_per_sec\": {:.1}, \"micros_per_query\": {:.1}}}{sep}\n",
            r.delta_fill, r.candidates, r.queries, r.queries_per_sec, r.micros_per_query,
        ));
    }
    out.push_str("  ],\n  \"compaction\": [\n");
    for (i, r) in compaction.iter().enumerate() {
        let sep = if i + 1 == compaction.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"threads\": {}, \"series\": {}, \"delta_fill\": {}, \
             \"tombstones\": {}, \"millis\": {:.3}}}{sep}\n",
            r.threads, r.series, r.delta_fill, r.tombstones, r.millis,
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// Write records as a JSON array. The offline build has no `serde`; the
/// records are flat, so manual formatting is sufficient and the output is
/// stable for line-diffing across PRs.
pub fn write_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        let sep = if i + 1 == records.len() { "" } else { "," };
        out.push_str(&format!(
            "  {{\"bound\": \"{}\", \"series_len\": {}, \"candidates\": {}, \"ns_per_op\": {:.1}}}{sep}\n",
            r.bound.replace('\\', "\\\\").replace('"', "\\\""),
            r.series_len,
            r.candidates,
            r.ns_per_op,
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}
