//! Cluster-pruning trajectory bench: k-NN throughput and cluster-level
//! prune rate as the per-shard cluster count sweeps {0, 4, 16, 64} over
//! a large synthetic candidate pool.
//!
//! `clusters = 0` is the flat baseline (every candidate enters the
//! per-candidate cascade). At `clusters > 0` each shard carries merged
//! cluster envelopes; one envelope-vs-query `LB_KEOGH` per cluster can
//! skip the whole cluster when its bound already exceeds the running
//! cutoff, so per-candidate work becomes sublinear in the pool size on
//! clusterable workloads. Neighbors are bit-identical at every setting
//! (the pruning is exact); a spot check asserts it per sweep point.
//!
//! Records land in `BENCH_cluster_prune.json`: queries/sec plus the
//! fraction of query × candidate pairs skipped at cluster level and the
//! raw cluster counters.
//!
//! Knobs (env): `DTWB_REPEATS` (default 3), `DTWB_SERIES_LEN` (128),
//! `DTWB_CANDIDATES` (10000), `DTWB_QUERIES` (16), `DTWB_THREADS` (4),
//! `DTWB_SHARDS` (4).
//!
//! ```sh
//! cargo bench --bench cluster_prune
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::index::{DtwIndex, QueryOptions};
use dtw_bounds::metrics::{Summary, Table};

/// Smooth random-walk series around a per-family offset: the families
/// give the pool genuine cluster structure (like repeated motifs in a
/// real archive) so cluster-level bounds have something to skip.
fn family_walk(rng: &mut Rng, l: usize, offset: f64) -> Vec<f64> {
    let mut v = offset;
    (0..l)
        .map(|_| {
            v += rng.normal() * 0.25;
            v
        })
        .collect()
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let l = env_usize("DTWB_SERIES_LEN", 128);
    let n = env_usize("DTWB_CANDIDATES", 10_000);
    let nq = env_usize("DTWB_QUERIES", 16);
    let threads = env_usize("DTWB_THREADS", 4);
    let shards = env_usize("DTWB_SHARDS", 4).max(1);
    let w = (l / 10).max(1);
    let mut rng = Rng::seeded(0xC1AB);

    // 12 well-separated families: enough spread that a query near one
    // family sees large cluster bounds on most of the others.
    let families = 12usize;
    let train: Vec<Vec<f64>> = (0..n)
        .map(|i| family_walk(&mut rng, l, 6.0 * (i % families) as f64))
        .collect();
    let queries: Vec<Vec<f64>> =
        (0..nq).map(|i| family_walk(&mut rng, l, 6.0 * (i % families) as f64)).collect();

    benchkit::banner(&format!(
        "Cluster-level pruning sweep (n={n}, l={l}, w={w}, k=3, \
         shards={shards}, threads={threads})"
    ));

    let opts = QueryOptions::k(3);
    let mut table = Table::new(vec![
        "clusters",
        "queries/s",
        "vs flat",
        "cluster prune",
        "clusters skipped",
    ]);
    let mut records: Vec<benchkit::ClusterPruneRecord> = Vec::new();
    let mut base_qps = 0.0f64;
    let mut baseline: Vec<Vec<f64>> = Vec::new();
    for &clusters in &[0usize, 4, 16, 64] {
        let mut builder = DtwIndex::builder(train.clone())
            .window(w)
            .shards(shards)
            .threads(threads);
        if clusters > 0 {
            builder = builder.clusters(clusters);
        }
        let index = builder.build().expect("one shared length");
        let mut searcher = index.searcher();

        // Exactness spot check against the flat baseline, every sweep
        // point, before timing.
        let answers: Vec<Vec<f64>> =
            queries.iter().map(|q| searcher.query_values::<Squared>(q, &opts).distances()).collect();
        if clusters == 0 {
            baseline = answers;
        } else {
            assert_eq!(baseline, answers, "clustered search must be bit-equal to flat");
        }

        let mean = Summary::of(&benchkit::time_reps(knobs.repeats, || {
            let mut acc = 0usize;
            for q in &queries {
                acc += searcher.query_values::<Squared>(q, &opts).neighbors.len();
            }
            std::hint::black_box(acc);
        }))
        .mean;
        let qps = nq as f64 / mean;
        if clusters == 0 {
            base_qps = qps;
        }

        // Counters from one untimed pass over the query set.
        let mut cluster_lb_calls = 0usize;
        let mut clusters_pruned = 0usize;
        let mut members_pruned = 0usize;
        for q in &queries {
            let out = searcher.query_values::<Squared>(q, &opts);
            cluster_lb_calls += out.stats.cluster_lb_calls;
            clusters_pruned += out.stats.clusters_pruned;
            members_pruned += out.stats.cluster_members_pruned;
        }
        let prune_rate = members_pruned as f64 / (nq * n) as f64;

        table.row(vec![
            clusters.to_string(),
            format!("{qps:.1}"),
            format!("{:.2}x", qps / base_qps),
            format!("{:.1}%", 100.0 * prune_rate),
            clusters_pruned.to_string(),
        ]);
        records.push(benchkit::ClusterPruneRecord {
            clusters,
            shards,
            threads,
            candidates: n,
            queries: nq,
            queries_per_sec: qps,
            cluster_prune_rate: prune_rate,
            cluster_lb_calls,
            clusters_pruned,
        });
    }
    println!("{}", table.to_markdown());

    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the trajectory file at the workspace root regardless.
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cluster_prune.json");
    benchkit::write_cluster_prune_json(out_path, &records)
        .expect("write BENCH_cluster_prune.json");
    println!("wrote BENCH_cluster_prune.json ({} records)", records.len());
}
