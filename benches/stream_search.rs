//! Streaming subsequence-search benchmark: samples/sec and per-stage
//! prune rate for each screening cascade, over the synthetic monitor
//! workload (pattern library + noise stream with embedded occurrences).
//! Writes `BENCH_stream_search.json` so the streaming-path perf
//! trajectory is tracked across PRs alongside `BENCH_nn_search.json`.
//!
//! ```sh
//! cargo bench --bench stream_search
//! DTWB_STREAM_LEN=8000 DTWB_REPEATS=1 cargo bench --bench stream_search  # quick pass
//! ```
//!
//! Knobs (environment): `DTWB_STREAM_LEN` (default 20000),
//! `DTWB_PATTERNS` (default 32), `DTWB_REPEATS` (default 3),
//! `DTWB_SEED` (default 2021).

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::rng::Rng;
use dtw_bounds::data::synthetic::{embed_stream, sinusoid_pattern};
use dtw_bounds::delta::Squared;
use dtw_bounds::index::DtwIndex;
use dtw_bounds::metrics::Table;
use dtw_bounds::stream::SubsequenceOptions;

const PATTERN_LEN: usize = 128;
const W: usize = 6;
const HOP: usize = 4;
const TAU: f64 = 18.0;

/// The cascades to compare, cheapest-to-tightest final stage.
fn cascades() -> Vec<Vec<BoundKind>> {
    vec![
        vec![BoundKind::KimFL],
        vec![BoundKind::KimFL, BoundKind::Keogh],
        vec![BoundKind::KimFL, BoundKind::Keogh, BoundKind::Webb],
        vec![BoundKind::KimFL, BoundKind::Keogh, BoundKind::Webb, BoundKind::Petitjean],
    ]
}

fn cascade_label(c: &[BoundKind]) -> String {
    c.iter().map(|b| b.name()).collect::<Vec<_>>().join("->")
}

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let stream_len: usize = std::env::var("DTWB_STREAM_LEN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let n_patterns: usize = std::env::var("DTWB_PATTERNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);

    let mut rng = Rng::seeded(knobs.seed);
    let patterns: Vec<Vec<f64>> =
        (0..n_patterns).map(|_| sinusoid_pattern(&mut rng, PATTERN_LEN)).collect();
    let index = DtwIndex::builder(patterns.clone())
        .labels((0..n_patterns as u32).collect())
        .window(W)
        .build()
        .expect("patterns share one length");

    let (stream, _embedded) = embed_stream(&mut rng, &patterns, stream_len, 0.08, 0.1, 0.15);

    benchkit::banner(&format!(
        "stream search: {n_patterns} patterns x {PATTERN_LEN}, stream {} samples, \
         hop {HOP}, tau {TAU}, {} repeats",
        stream.len(),
        knobs.repeats
    ));

    let mut table =
        Table::new(vec!["cascade", "samples/s", "prune rate", "dtw calls", "matches"]);
    let mut records = Vec::new();

    for cascade in cascades() {
        let label = cascade_label(&cascade);
        let opts = SubsequenceOptions::threshold(TAU)
            .with_hop(HOP)
            .with_znorm(true)
            .with_cascade(cascade);

        // Warmup once, then timed repeats (fresh searcher per pass —
        // the searcher state is one stream's pass).
        let mut report = index
            .subsequence_scan::<Squared>(&stream, opts.clone())
            .expect("valid options");
        let mut busy = 0.0f64;
        for _ in 0..knobs.repeats {
            report = index
                .subsequence_scan::<Squared>(&stream, opts.clone())
                .expect("valid options");
            busy += report.busy.as_secs_f64();
        }
        let stats = &report.stats;
        let per_repeat = busy / knobs.repeats.max(1) as f64;
        // Zero busy time (e.g. a stream shorter than one window) must not
        // poison the JSON with `inf`.
        let sps = if per_repeat > 0.0 { stats.samples as f64 / per_repeat } else { 0.0 };
        let pairs = stats.candidates.max(1) as f64;
        let stage_prune: Vec<(String, f64)> = stats
            .stages
            .iter()
            .map(|s| (s.bound.name(), s.pruned as f64 / pairs))
            .collect();

        table.row(vec![
            label.clone(),
            format!("{sps:.0}"),
            format!("{:.1}%", 100.0 * stats.prune_rate()),
            format!("{}", stats.dtw_calls),
            format!("{}", stats.matches),
        ]);
        records.push(benchkit::StreamSearchRecord {
            cascade: label,
            samples: stats.samples as usize,
            windows: stats.windows as usize,
            matches: stats.matches as usize,
            samples_per_sec: sps,
            prune_rate: stats.prune_rate(),
            stage_prune,
            dtw_calls: stats.dtw_calls as usize,
        });
    }

    println!("{}", table.to_markdown());
    println!("(per-stage rates in BENCH_stream_search.json count pairs rejected at that");
    println!(" stage; every cascade answers identically — only the screening cost moves)");
    benchkit::write_stream_search_json("BENCH_stream_search.json", &records)
        .expect("write BENCH_stream_search.json");
    println!("wrote BENCH_stream_search.json ({} records)", records.len());
}
