//! Tables 1, 2, 3 and Figures 29, 30 (§6.3): classification time over the
//! whole archive with windows at 1%, 10% and 20% of series length
//! (rounded up), sorted-order search, eight pairings per table.
//!
//! ```sh
//! cargo bench --bench table_window_sweep
//! DTWB_TAKE=20 cargo bench --bench table_window_sweep   # quick pass
//! ```

#[path = "benchkit.rs"]
mod benchkit;

use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec};
use dtw_bounds::data::Dataset;
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::nn_timing::scatter_table;
use dtw_bounds::experiments::window_sweep;

fn main() {
    let knobs = benchkit::Knobs::from_env();
    let archive = generate_archive(&ArchiveSpec::new(knobs.scale, knobs.seed));
    let datasets: Vec<&Dataset> = archive.iter().collect();
    let take = knobs.take_of(datasets.len(), usize::MAX);
    let datasets = &datasets[..take];

    for (frac, label) in [(0.01, "Table 1"), (0.10, "Table 2"), (0.20, "Table 3")] {
        benchkit::banner(&format!(
            "{label}: all {} datasets, w = {:.0}% · l, {} repeats",
            datasets.len(),
            frac * 100.0,
            knobs.repeats
        ));
        let res = window_sweep::<Squared>(datasets, frac, knobs.repeats, knobs.seed);
        println!("{}", res.to_table().to_markdown());

        // Figures 29 (1%) and 30 (20%): Webb vs Enhanced* scatter.
        if frac != 0.10 {
            let webb = res.columns.iter().find(|c| c.label == "LB_Webb").unwrap();
            let enh = res.columns.iter().find(|c| c.label == "LB_Enhanced*").unwrap();
            println!(
                "Figure {}: scatter Webb vs Enhanced*:",
                if frac < 0.05 { 29 } else { 30 }
            );
            println!("{}", scatter_table(webb, enh).to_csv());
        }
    }
}
