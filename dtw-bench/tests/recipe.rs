//! Recipe contract tests: every shipped recipe parses, the canonical
//! form round-trips every field, and malformed input fails with the
//! right typed error pointing at the right place.

use std::fs;
use std::path::PathBuf;

use dtw_bench::recipe::{
    DatasetSpec, Family, Grid, LiveSpec, OracleMode, QueryMix, QuerySpec, Recipe, RecipeError,
    ScenarioKind, StreamSpec, WalMode,
};

fn recipes_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("recipes")
}

fn sample() -> Recipe {
    Recipe {
        name: "it".into(),
        description: "integration sample".into(),
        seed: 99,
        dataset: DatasetSpec {
            family: Family::Adversarial,
            series: 40,
            len: 48,
            window: 5,
            classes: 8,
        },
        queries: QuerySpec { count: 7, mix: QueryMix::Near, k: 4 },
        grid: Grid { threads: vec![1, 2, 4], shards: vec![1, 4], clusters: vec![0, 5] },
        scenarios: ScenarioKind::ALL.to_vec(),
        stream: StreamSpec { samples: 640, hop: 3, threshold: 7.25 },
        live: LiveSpec {
            inserts: 10,
            deletes: 4,
            wal: vec![WalMode::Off, WalMode::Always],
        },
        oracle: OracleMode::Cross,
    }
}

#[test]
fn every_shipped_recipe_parses_and_round_trips() {
    let mut seen = 0;
    for entry in fs::read_dir(recipes_dir()).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().map_or(true, |x| x != "toml") {
            continue;
        }
        seen += 1;
        let text = fs::read_to_string(&path).unwrap();
        let recipe = Recipe::parse(&text)
            .unwrap_or_else(|e| panic!("{} does not parse: {e}", path.display()));
        assert_eq!(
            recipe.name,
            path.file_stem().unwrap().to_string_lossy(),
            "recipe name must match its file name"
        );
        let reparsed = Recipe::parse(&recipe.to_toml_string()).unwrap();
        assert_eq!(reparsed, recipe, "{} canonical form drifts", path.display());
    }
    assert!(seen >= 2, "expected at least quick + full recipes, found {seen}");
}

#[test]
fn round_trip_preserves_every_field() {
    let r = sample();
    assert_eq!(Recipe::parse(&r.to_toml_string()).unwrap(), r);
}

#[test]
fn unknown_table_key_and_rootless_key_are_rejected_with_lines() {
    let mut text = sample().to_toml_string();
    text.push_str("[mystery]\nx = 1\n");
    let lines = text.lines().count();
    match Recipe::parse(&text).unwrap_err() {
        RecipeError::UnknownTable { table, line } => {
            assert_eq!(table, "mystery");
            assert_eq!(line, lines - 1);
        }
        other => panic!("want UnknownTable, got {other:?}"),
    }

    let text = sample().to_toml_string().replace("hop = 3", "hop = 3\nhopp = 4");
    match Recipe::parse(&text).unwrap_err() {
        RecipeError::UnknownKey { table, key, .. } => {
            assert_eq!((table.as_str(), key.as_str()), ("stream", "hopp"));
        }
        other => panic!("want UnknownKey, got {other:?}"),
    }

    match Recipe::parse("loose = 1\n[recipe]\nname = \"x\"\n").unwrap_err() {
        RecipeError::UnknownKey { table, key, line } => {
            assert_eq!(table, "");
            assert_eq!(key, "loose");
            assert_eq!(line, 1);
        }
        other => panic!("want rootless UnknownKey, got {other:?}"),
    }
}

#[test]
fn missing_keys_and_tables_are_reported() {
    let text = sample().to_toml_string().replace("window = 5\n", "");
    assert_eq!(
        Recipe::parse(&text).unwrap_err(),
        RecipeError::MissingKey { table: "dataset".into(), key: "window".into() }
    );
    let text: String = sample()
        .to_toml_string()
        .lines()
        .skip_while(|l| !l.starts_with("[dataset]"))
        .map(|l| format!("{l}\n"))
        .collect();
    // [recipe] was dropped entirely.
    assert_eq!(
        Recipe::parse(&text).unwrap_err(),
        RecipeError::MissingKey { table: "recipe".into(), key: "*".into() }
    );
}

#[test]
fn invalid_values_name_table_key_and_line() {
    let text = sample().to_toml_string().replace("family = \"adversarial\"", "family = \"fractal\"");
    match Recipe::parse(&text).unwrap_err() {
        RecipeError::InvalidValue { table, key, line, message } => {
            assert_eq!((table.as_str(), key.as_str()), ("dataset", "family"));
            assert!(line > 0);
            assert!(message.contains("fractal"), "{message}");
        }
        other => panic!("want InvalidValue, got {other:?}"),
    }
    let text = sample().to_toml_string().replace("seed = 99", "seed = -1");
    assert!(matches!(Recipe::parse(&text), Err(RecipeError::InvalidValue { .. })));
    let text = sample()
        .to_toml_string()
        .replace("run = [\"cold-start\"", "run = [\"cold-start\", \"cold-start\"");
    assert!(matches!(Recipe::parse(&text), Err(RecipeError::InvalidValue { .. })));
}

#[test]
fn grid_validation_covers_every_axis() {
    let cases: Vec<(&str, &str)> = vec![
        ("threads = [1, 2, 4]", "threads = []"),
        ("threads = [1, 2, 4]", "threads = [0]"),
        ("shards = [1, 4]", "shards = [41]"),
        ("clusters = [0, 5]", "clusters = [41]"),
        ("samples = 640", "samples = 10"),
        ("hop = 3", "hop = 0"),
        ("threshold = 7.25", "threshold = 0.0"),
        ("deletes = 4", "deletes = 40"),
        ("wal = [\"off\", \"always\"]", "wal = []"),
        ("k = 4", "k = 41"),
        ("classes = 8", "classes = 0"),
    ];
    for (from, to) in cases {
        let text = sample().to_toml_string().replace(from, to);
        assert_ne!(text, sample().to_toml_string(), "replacement {from:?} did not apply");
        match Recipe::parse(&text) {
            Err(RecipeError::InvalidGrid { .. }) => {}
            other => panic!("{from} -> {to}: want InvalidGrid, got {other:?}"),
        }
    }
}

#[test]
fn toml_syntax_errors_surface_with_line_numbers() {
    match Recipe::parse("[recipe\nname = \"x\"\n").unwrap_err() {
        RecipeError::Toml { line, .. } => assert_eq!(line, 1),
        other => panic!("want Toml, got {other:?}"),
    }
}
