//! Report schema tests: the emitted JSON is pinned to a checked-in
//! golden file (so schema drift is a reviewed diff, not an accident),
//! and parse/emit round-trips every field.

use std::fs;
use std::path::PathBuf;

use dtw_bench::gate;
use dtw_bench::report::{Metric, Report, SCHEMA_VERSION};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden").join("bench-report.json")
}

fn golden_report() -> Report {
    Report {
        schema_version: SCHEMA_VERSION,
        recipe: "golden".into(),
        seed: 42,
        oracle_mode: "brute".into(),
        oracle_checks: 1234,
        scenarios: vec!["knn".into(), "stream".into()],
        metrics: vec![
            Metric::lower("knn/t1.s1.c0/ns_per_query", 52340.0, "ns"),
            Metric::higher("knn/t1.s1.c0/prune_rate", 0.875, "ratio").with_tolerance(0.5),
            Metric::lower("stream/t2.s2.c4/windows", 569.0, "count").with_tolerance(0.0),
        ],
    }
}

#[test]
fn emitted_json_matches_the_golden_file_byte_for_byte() {
    let want = fs::read_to_string(golden_path()).unwrap();
    assert_eq!(
        golden_report().to_json(),
        want,
        "report schema drifted from tests/golden/bench-report.json — \
         if intentional, bump SCHEMA_VERSION and regenerate the golden file"
    );
}

#[test]
fn golden_file_parses_back_to_the_same_report() {
    let text = fs::read_to_string(golden_path()).unwrap();
    assert_eq!(Report::parse(&text).unwrap(), golden_report());
}

#[test]
fn parse_emit_round_trip_is_stable_for_awkward_values() {
    let mut r = golden_report();
    r.metrics.push(Metric::lower("x/t1.s1.c0/ratio", 0.1 + 0.2, "ratio"));
    r.metrics.push(Metric::higher("y/t1.s1.c0/tiny", 1e-9, "ratio").with_tolerance(0.333));
    r.recipe = "with \"quotes\" and \\slash".into();
    let once = Report::parse(&r.to_json()).unwrap();
    assert_eq!(once, r);
    // Fixed point: a second emit/parse cycle changes nothing.
    assert_eq!(once.to_json(), Report::parse(&once.to_json()).unwrap().to_json());
}

#[test]
fn checked_in_baseline_is_parseable_and_gates_trivially() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("baseline.json");
    let baseline = Report::load(&path).unwrap();
    assert_eq!(baseline.schema_version, SCHEMA_VERSION);
    let outcome = gate::check(&golden_report(), &baseline);
    assert!(outcome.passed());
}
