//! The regression gate: compare a fresh report against the checked-in
//! baseline.
//!
//! This is the single successor to the old `benches/check_regression.rs`
//! driver. Direction comes from each metric's `higher_is_better` flag;
//! the allowed relative slack comes from the **baseline** metric's
//! `tolerance` field when present (so noisy metrics opt into wider
//! bands in one reviewed place), else [`DEFAULT_TOLERANCE`]. Metrics
//! present on only one side are notes, not failures — adding a metric
//! must not break CI, and a metric disappearing is surfaced without
//! blocking until the baseline is re-recorded.

use crate::report::Report;

/// Relative slack when the baseline metric carries no tolerance.
pub const DEFAULT_TOLERANCE: f64 = 0.20;

/// One regression: a metric that moved past its tolerance in the bad
/// direction.
#[derive(Debug, Clone, PartialEq)]
pub struct Regression {
    /// Metric id.
    pub id: String,
    /// Baseline value.
    pub baseline: f64,
    /// Current value.
    pub current: f64,
    /// The tolerance that was applied.
    pub tolerance: f64,
    /// Direction of the metric.
    pub higher_is_better: bool,
}

impl std::fmt::Display for Regression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let dir = if self.higher_is_better { "dropped" } else { "rose" };
        write!(
            f,
            "{}: {} {:.4} -> {:.4} (tolerance {:.0}%)",
            self.id,
            dir,
            self.baseline,
            self.current,
            self.tolerance * 100.0
        )
    }
}

/// Gate verdict: what was checked, what regressed, what was skipped.
#[derive(Debug, Default)]
pub struct GateOutcome {
    /// Metrics compared against the baseline.
    pub checked: usize,
    /// Metrics past tolerance in the bad direction.
    pub regressions: Vec<Regression>,
    /// Non-fatal observations (missing metrics, empty baseline).
    pub notes: Vec<String>,
}

impl GateOutcome {
    /// True when nothing regressed.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Compare `current` against `baseline`.
pub fn check(current: &Report, baseline: &Report) -> GateOutcome {
    let mut out = GateOutcome::default();
    if baseline.metrics.is_empty() {
        out.notes.push("baseline has no metrics; gate passes trivially".to_string());
        return out;
    }
    for base in &baseline.metrics {
        let Some(cur) = current.metric(&base.id) else {
            out.notes.push(format!("baseline metric `{}` missing from current report", base.id));
            continue;
        };
        out.checked += 1;
        let tolerance = base.tolerance.unwrap_or(DEFAULT_TOLERANCE);
        // Relative to the baseline magnitude; a zero baseline gets an
        // absolute band of `tolerance` so ratios that start at 0 can
        // still move a little.
        let slack = if base.value == 0.0 { tolerance } else { base.value.abs() * tolerance };
        let bad = if base.higher_is_better {
            cur.value < base.value - slack
        } else {
            cur.value > base.value + slack
        };
        if bad {
            out.regressions.push(Regression {
                id: base.id.clone(),
                baseline: base.value,
                current: cur.value,
                tolerance,
                higher_is_better: base.higher_is_better,
            });
        }
    }
    for cur in &current.metrics {
        if baseline.metric(&cur.id).is_none() {
            out.notes.push(format!("new metric `{}` has no baseline yet", cur.id));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::{Metric, Report, SCHEMA_VERSION};

    fn report(metrics: Vec<Metric>) -> Report {
        Report {
            schema_version: SCHEMA_VERSION,
            recipe: "gate-unit".into(),
            seed: 0,
            oracle_mode: "brute".into(),
            oracle_checks: 0,
            scenarios: vec![],
            metrics,
        }
    }

    #[test]
    fn direction_and_default_tolerance() {
        let base = report(vec![
            Metric::lower("a/ns", 100.0, "ns"),
            Metric::higher("a/rate", 0.5, "ratio"),
        ]);
        // +19% on lower-is-better and -19% on higher-is-better: inside
        // the 20% default band.
        let ok = report(vec![
            Metric::lower("a/ns", 119.0, "ns"),
            Metric::higher("a/rate", 0.405, "ratio"),
        ]);
        assert!(check(&ok, &base).passed());
        // Past the band in the bad direction on both.
        let bad = report(vec![
            Metric::lower("a/ns", 121.0, "ns"),
            Metric::higher("a/rate", 0.39, "ratio"),
        ]);
        let outcome = check(&bad, &base);
        assert_eq!(outcome.regressions.len(), 2);
        // Improvements never fail, however large.
        let better = report(vec![
            Metric::lower("a/ns", 1.0, "ns"),
            Metric::higher("a/rate", 0.99, "ratio"),
        ]);
        assert!(check(&better, &base).passed());
    }

    #[test]
    fn per_metric_tolerance_overrides_default() {
        let base = report(vec![Metric::lower("a/ns", 100.0, "ns").with_tolerance(0.5)]);
        let cur = report(vec![Metric::lower("a/ns", 149.0, "ns")]);
        assert!(check(&cur, &base).passed());
        let cur = report(vec![Metric::lower("a/ns", 151.0, "ns")]);
        assert!(!check(&cur, &base).passed());
    }

    #[test]
    fn missing_and_new_metrics_are_notes_not_failures() {
        let base = report(vec![Metric::lower("gone/ns", 10.0, "ns")]);
        let cur = report(vec![Metric::lower("new/ns", 10.0, "ns")]);
        let outcome = check(&cur, &base);
        assert!(outcome.passed());
        assert_eq!(outcome.checked, 0);
        assert_eq!(outcome.notes.len(), 2);
        let empty = report(vec![]);
        let outcome = check(&cur, &empty);
        assert!(outcome.passed());
        assert!(outcome.notes[0].contains("trivially"));
    }
}
