//! Exactness oracles: independent reference answers and conservation
//! identities.
//!
//! The reference path deliberately shares no code with the index: it
//! runs the plain full-matrix DP kernel (`dtw::<Squared>`, no cutoff,
//! no bounds, no cascade) over every candidate and sorts by the same
//! `(distance, index)` total order the index's `KnnSet` maintains. The
//! paper's lower bounds are admissible and the kernels' early-abandon
//! cutoffs only skip work that cannot change surviving results, so any
//! engine configuration must reproduce the reference answers **bit for
//! bit** — a `1e-9`-style tolerance would paper over exactly the class
//! of bug this suite exists to catch.

use dtw_bounds::delta::Squared;
use dtw_bounds::dtw::dtw;
use dtw_bounds::search::nn::SearchStats;

/// Result triple the oracles compare on: `(index, label, distance)`.
pub type Triple = (usize, u32, f64);

/// A stream match quadruple: `(window start, index, label, distance)`.
pub type StreamTriple = (u64, usize, u32, f64);

/// An oracle failure: which check tripped, and the mismatch.
#[derive(Debug, Clone)]
pub struct OracleError {
    /// Which check failed (e.g. `knn bit-equality`).
    pub check: String,
    /// Context: scenario, grid tag, query id.
    pub context: String,
    /// The mismatch, expected vs. got.
    pub detail: String,
}

impl std::fmt::Display for OracleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} failed at {}: {}", self.check, self.context, self.detail)
    }
}

impl std::error::Error for OracleError {}

/// Counts every individual assertion that passed, so the report proves
/// the oracles actually ran.
#[derive(Debug, Default)]
pub struct Oracle {
    /// Total assertions checked (bit-equality triples + identities).
    pub checks: u64,
}

impl Oracle {
    fn fail(
        &self,
        check: &str,
        context: &str,
        detail: String,
    ) -> Result<(), OracleError> {
        Err(OracleError {
            check: check.to_string(),
            context: context.to_string(),
            detail,
        })
    }

    /// Assert two result lists are identical, including f64 bits.
    pub fn check_triples(
        &mut self,
        context: &str,
        got: &[Triple],
        want: &[Triple],
    ) -> Result<(), OracleError> {
        self.checks += 1;
        if got.len() != want.len() {
            return self.fail(
                "knn bit-equality",
                context,
                format!("result count: got {}, want {}", got.len(), want.len()),
            );
        }
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            if g.0 != w.0 || g.1 != w.1 || g.2.to_bits() != w.2.to_bits() {
                return self.fail(
                    "knn bit-equality",
                    context,
                    format!("rank {i}: got {g:?}, want {w:?}"),
                );
            }
        }
        Ok(())
    }

    /// Assert two stream match lists are identical, including f64 bits.
    pub fn check_stream(
        &mut self,
        context: &str,
        got: &[StreamTriple],
        want: &[StreamTriple],
    ) -> Result<(), OracleError> {
        self.checks += 1;
        if got.len() != want.len() {
            return self.fail(
                "stream bit-equality",
                context,
                format!("match count: got {}, want {}", got.len(), want.len()),
            );
        }
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            if g.0 != w.0 || g.1 != w.1 || g.2 != w.2 || g.3.to_bits() != w.3.to_bits() {
                return self.fail(
                    "stream bit-equality",
                    context,
                    format!("match {i}: got {g:?}, want {w:?}"),
                );
            }
        }
        Ok(())
    }

    /// Prune-counter conservation for a frozen-index k-NN query: every
    /// candidate is either pruned (by a bound or a cluster) or costed.
    pub fn check_knn_conservation(
        &mut self,
        context: &str,
        stats: &SearchStats,
        candidates: usize,
    ) -> Result<(), OracleError> {
        self.checks += 1;
        let accounted = stats.dtw_calls + stats.pruned + stats.cluster_members_pruned;
        if accounted != candidates {
            return self.fail(
                "knn prune conservation",
                context,
                format!(
                    "dtw_calls {} + pruned {} + cluster_members_pruned {} = {} != candidates {}",
                    stats.dtw_calls, stats.pruned, stats.cluster_members_pruned, accounted,
                    candidates
                ),
            );
        }
        Ok(())
    }

    /// Delta-shard conservation for a live query: every scanned delta
    /// row is either pruned or costed.
    pub fn check_delta_conservation(
        &mut self,
        context: &str,
        stats: &SearchStats,
    ) -> Result<(), OracleError> {
        self.checks += 1;
        if stats.delta_scanned != stats.delta_pruned + stats.delta_dtw {
            return self.fail(
                "delta prune conservation",
                context,
                format!(
                    "delta_scanned {} != delta_pruned {} + delta_dtw {}",
                    stats.delta_scanned, stats.delta_pruned, stats.delta_dtw
                ),
            );
        }
        Ok(())
    }

    /// A named scalar identity (`got == want`), used for the stream
    /// cascade's per-stage conservation chain.
    pub fn check_identity(
        &mut self,
        context: &str,
        what: &str,
        got: u64,
        want: u64,
    ) -> Result<(), OracleError> {
        self.checks += 1;
        if got != want {
            return self.fail(
                "stream conservation",
                context,
                format!("{what}: got {got}, want {want}"),
            );
        }
        Ok(())
    }
}

/// Reference k-NN: full-matrix DTW against every candidate, sorted by
/// the engine's `(distance, index)` total order, truncated to `k`.
pub fn reference_knn(
    train: &[Vec<f64>],
    labels: &[u32],
    w: usize,
    query: &[f64],
    k: usize,
) -> Vec<Triple> {
    let mut all: Vec<Triple> = train
        .iter()
        .enumerate()
        .map(|(i, s)| (i, labels[i], dtw::<Squared>(query, s, w)))
        .collect();
    all.sort_by(|a, b| {
        a.2.partial_cmp(&b.2).expect("DTW distances are finite").then(a.0.cmp(&b.0))
    });
    all.truncate(k);
    all
}

/// Reference subsequence scan: for every hop-aligned window, the
/// nearest pattern by full-matrix DTW (ties to the lower index, the
/// engine's order), reported iff strictly under the threshold.
pub fn reference_stream(
    train: &[Vec<f64>],
    labels: &[u32],
    w: usize,
    samples: &[f64],
    len: usize,
    hop: usize,
    threshold: f64,
) -> Vec<StreamTriple> {
    let mut out = Vec::new();
    let mut start = 0usize;
    while start + len <= samples.len() {
        if start % hop == 0 {
            let window = &samples[start..start + len];
            let mut best: Option<(usize, f64)> = None;
            for (i, s) in train.iter().enumerate() {
                let d = dtw::<Squared>(window, s, w);
                if best.map_or(true, |(_, bd)| d < bd) {
                    best = Some((i, d));
                }
            }
            if let Some((i, d)) = best {
                if d < threshold {
                    out.push((start as u64, i, labels[i], d));
                }
            }
        }
        start += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use dtw_bounds::data::rng::Rng;
    use dtw_bounds::data::synthetic::sinusoid_pattern;

    #[test]
    fn reference_knn_orders_by_distance_then_index() {
        let mut rng = Rng::seeded(11);
        let train: Vec<Vec<f64>> = (0..6).map(|_| sinusoid_pattern(&mut rng, 20)).collect();
        let labels = vec![0u32, 1, 0, 1, 0, 1];
        // Duplicate series 0 at index 3: identical distances must
        // tie-break to the lower index.
        let mut train = train;
        train[3] = train[0].clone();
        let q = sinusoid_pattern(&mut rng, 20);
        let got = reference_knn(&train, &labels, 2, &q, 6);
        for pair in got.windows(2) {
            assert!(
                pair[0].2 < pair[1].2 || (pair[0].2 == pair[1].2 && pair[0].0 < pair[1].0),
                "order violated: {pair:?}"
            );
        }
        let dup_ranks: Vec<usize> =
            got.iter().filter(|t| t.0 == 0 || t.0 == 3).map(|t| t.0).collect();
        assert_eq!(dup_ranks, vec![0, 3]);
    }

    #[test]
    fn oracle_counts_checks_and_reports_mismatches() {
        let mut o = Oracle::default();
        let a = vec![(0usize, 0u32, 1.0f64)];
        o.check_triples("ctx", &a, &a).unwrap();
        assert_eq!(o.checks, 1);
        let b = vec![(0usize, 0u32, 1.0f64 + f64::EPSILON)];
        let e = o.check_triples("ctx", &a, &b).unwrap_err();
        assert!(e.to_string().contains("ctx"), "{e}");
        assert_eq!(o.checks, 2);
    }

    #[test]
    fn reference_stream_respects_hop_and_strict_threshold() {
        let mut rng = Rng::seeded(5);
        let train: Vec<Vec<f64>> = (0..3).map(|_| sinusoid_pattern(&mut rng, 16)).collect();
        let labels = vec![0u32, 1, 2];
        let samples: Vec<f64> = (0..64).map(|i| (i as f64 * 0.3).sin()).collect();
        let hits = reference_stream(&train, &labels, 2, &samples, 16, 4, 1e9);
        // Permissive threshold: every hop-aligned window matches.
        let expected_windows = (64 - 16) / 4 + 1;
        assert_eq!(hits.len(), expected_windows);
        assert!(hits.iter().all(|h| h.0 % 4 == 0));
        let none = reference_stream(&train, &labels, 2, &samples, 16, 4, 0.0);
        assert!(none.is_empty());
    }
}
