//! `dtw-bench`: recipe-driven scenario benchmarks for `dtw-bounds`,
//! with built-in exactness oracles.
//!
//! The suite exists to answer two questions at once, for every change:
//! *did it get slower?* and *is it still exact?* A TOML
//! [recipe](crate::recipe) declares a synthetic workload (dataset
//! family, query mix, a thread × shard × cluster grid) and a list of
//! [scenarios](crate::scenario) — cold start, steady-state k-NN,
//! batched screening, stream firehose, snapshot round-trip, and live
//! mutation. The [runner](crate::runner) wraps every scenario in
//! [oracles](crate::oracle) that hold each answer to **bit-equality**
//! against an independent full-matrix DTW reference and check the
//! prune-counter conservation identities, then emits one
//! schema-versioned [report](crate::report) that the regression
//! [gate](crate::gate) compares against a checked-in baseline.
//!
//! The `dtw-bench` binary fronts all of it:
//!
//! ```text
//! dtw-bench run --recipe quick          # run, verify, report
//! dtw-bench check --report bench-report.json
//! dtw-bench recipes                     # list available recipes
//! ```
//!
//! See `docs/benchmarks.md` for the full workflow.

pub mod dataset;
pub mod gate;
pub mod oracle;
pub mod recipe;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod toml;
