//! Minimal TOML subset parser for bench recipes.
//!
//! The offline build carries no `toml`/`serde` crates, so recipes are
//! parsed by hand. The supported subset is exactly what recipe files
//! need: `[table]` headers, `key = value` entries, and scalar values
//! (strings, integers, floats, booleans) plus flat arrays of scalars.
//! Comments (`# ...`) are allowed on their own line or after a value.
//!
//! Every error carries the 1-based source line, so recipe mistakes point
//! at the offending line instead of failing opaquely.

use std::fmt;

/// One parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A `"quoted"` string (escapes: `\\`, `\"`, `\n`, `\t`).
    Str(String),
    /// A decimal integer.
    Int(i64),
    /// A float (anything `f64::from_str` accepts that is not an integer).
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of scalars; nested arrays are rejected.
    Array(Vec<Value>),
}

impl Value {
    /// Human-readable type name for error messages.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::Array(_) => "array",
        }
    }
}

/// One `key = value` entry with its source line.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    /// The key (bare, `[A-Za-z0-9_-]+`).
    pub key: String,
    /// The parsed value.
    pub value: Value,
    /// 1-based source line of the entry.
    pub line: usize,
}

/// One `[name]` table and its entries. Keys before any header live in
/// the root table (empty name).
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    /// Table name (empty for the root table).
    pub name: String,
    /// 1-based source line of the header (0 for the root table).
    pub line: usize,
    /// Entries in file order.
    pub entries: Vec<Entry>,
}

/// A parsed document: tables in file order.
#[derive(Debug, Clone, PartialEq)]
pub struct Doc {
    /// Tables in file order; the root table appears only when it has
    /// entries.
    pub tables: Vec<Table>,
}

/// A parse failure, pointing at its 1-based source line.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// 1-based source line.
    pub line: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, message: impl Into<String>) -> ParseError {
    ParseError { line, message: message.into() }
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Parse a quoted string starting at `s[0] == '"'`; returns the string
/// and the rest of the line after the closing quote.
fn parse_str(s: &str, line: usize) -> Result<(String, &str), ParseError> {
    let mut out = String::new();
    let mut chars = s.char_indices();
    chars.next(); // opening quote
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((out, &s[i + 1..])),
            '\\' => match chars.next() {
                Some((_, '\\')) => out.push('\\'),
                Some((_, '"')) => out.push('"'),
                Some((_, 'n')) => out.push('\n'),
                Some((_, 't')) => out.push('\t'),
                Some((_, other)) => {
                    return Err(err(line, format!("unknown escape `\\{other}` in string")))
                }
                None => return Err(err(line, "unterminated escape in string")),
            },
            _ => out.push(c),
        }
    }
    Err(err(line, "unterminated string"))
}

/// Parse a bare scalar (no quotes, no array): bool, integer or float.
fn parse_scalar(s: &str, line: usize) -> Result<Value, ParseError> {
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        if f.is_finite() {
            return Ok(Value::Float(f));
        }
    }
    Err(err(line, format!("cannot parse value `{s}`")))
}

/// True when the rest of a line is only whitespace or a comment.
fn only_trailing(s: &str) -> bool {
    let t = s.trim_start();
    t.is_empty() || t.starts_with('#')
}

/// Parse the value part of a `key = value` line.
fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim_start();
    if s.is_empty() || s.starts_with('#') {
        return Err(err(line, "missing value after `=`"));
    }
    if s.starts_with('"') {
        let (v, rest) = parse_str(s, line)?;
        if !only_trailing(rest) {
            return Err(err(line, "unexpected characters after string value"));
        }
        return Ok(Value::Str(v));
    }
    if let Some(body) = s.strip_prefix('[') {
        // Scan to the matching `]`, tracking string state so commas and
        // brackets inside strings are inert.
        let mut in_str = false;
        let mut escaped = false;
        let mut end = None;
        for (i, c) in body.char_indices() {
            if in_str {
                if escaped {
                    escaped = false;
                } else if c == '\\' {
                    escaped = true;
                } else if c == '"' {
                    in_str = false;
                }
            } else if c == '"' {
                in_str = true;
            } else if c == '[' {
                return Err(err(line, "nested arrays are not supported"));
            } else if c == ']' {
                end = Some(i);
                break;
            }
        }
        let end = end.ok_or_else(|| err(line, "unterminated array"))?;
        if !only_trailing(&body[end + 1..]) {
            return Err(err(line, "unexpected characters after array value"));
        }
        let inner = &body[..end];
        let mut items = Vec::new();
        for piece in split_top_level(inner) {
            let piece = piece.trim();
            if piece.is_empty() {
                continue; // permits a trailing comma
            }
            if piece.starts_with('"') {
                let (v, rest) = parse_str(piece, line)?;
                if !rest.trim().is_empty() {
                    return Err(err(line, "unexpected characters after array string"));
                }
                items.push(Value::Str(v));
            } else {
                items.push(parse_scalar(piece, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    // Bare scalar: strip a trailing comment (no strings here), then parse.
    let body = match s.find('#') {
        Some(i) => s[..i].trim(),
        None => s.trim(),
    };
    parse_scalar(body, line)
}

/// Split array contents on top-level commas (commas inside strings are
/// inert).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else if c == '"' {
            in_str = true;
        } else if c == ',' {
            out.push(&s[start..i]);
            start = i + 1;
        }
    }
    out.push(&s[start..]);
    out
}

impl Doc {
    /// Parse a document; the first error aborts with its line number.
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut tables: Vec<Table> = Vec::new();
        let mut current = Table { name: String::new(), line: 0, entries: Vec::new() };
        for (idx, raw) in text.lines().enumerate() {
            let line = idx + 1;
            let t = raw.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            if let Some(rest) = t.strip_prefix('[') {
                let end = rest
                    .find(']')
                    .ok_or_else(|| err(line, "unterminated table header"))?;
                let name = rest[..end].trim();
                if !is_bare_key(name) {
                    return Err(err(line, format!("invalid table name `{name}`")));
                }
                if !only_trailing(&rest[end + 1..]) {
                    return Err(err(line, "unexpected characters after table header"));
                }
                if !current.entries.is_empty() || !current.name.is_empty() {
                    tables.push(current);
                }
                current = Table { name: name.to_string(), line, entries: Vec::new() };
                continue;
            }
            let (key, value) = t
                .split_once('=')
                .ok_or_else(|| err(line, "expected `key = value` or `[table]`"))?;
            let key = key.trim();
            if !is_bare_key(key) {
                return Err(err(line, format!("invalid key `{key}`")));
            }
            let value = parse_value(value, line)?;
            current.entries.push(Entry { key: key.to_string(), value, line });
        }
        if !current.entries.is_empty() || !current.name.is_empty() {
            tables.push(current);
        }
        Ok(Doc { tables })
    }

    /// Look up a table by name.
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.iter().find(|t| t.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_scalars_and_arrays() {
        let doc = Doc::parse(
            "# recipe\n[recipe]\nname = \"quick\" # inline comment\nseed = 77\n\n[grid]\nthreads = [1, 2, 4]\nratio = 0.25\nlive = true\n",
        )
        .unwrap();
        assert_eq!(doc.tables.len(), 2);
        let r = doc.table("recipe").unwrap();
        assert_eq!(r.entries[0].value, Value::Str("quick".into()));
        assert_eq!(r.entries[1].value, Value::Int(77));
        assert_eq!(r.entries[1].line, 4);
        let g = doc.table("grid").unwrap();
        assert_eq!(
            g.entries[0].value,
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(4)])
        );
        assert_eq!(g.entries[1].value, Value::Float(0.25));
        assert_eq!(g.entries[2].value, Value::Bool(true));
    }

    #[test]
    fn string_escapes_and_hash_inside_string() {
        let doc = Doc::parse("s = \"a#b \\\"q\\\" \\\\ end\"\n").unwrap();
        assert_eq!(doc.tables[0].entries[0].value, Value::Str("a#b \"q\" \\ end".into()));
    }

    #[test]
    fn root_table_collects_headerless_keys() {
        let doc = Doc::parse("x = 1\n[t]\ny = 2\n").unwrap();
        assert_eq!(doc.tables[0].name, "");
        assert_eq!(doc.tables[0].entries[0].key, "x");
        assert_eq!(doc.tables[1].name, "t");
    }

    #[test]
    fn errors_carry_line_numbers() {
        assert_eq!(Doc::parse("a\nb = \n").unwrap_err().line, 1);
        assert_eq!(Doc::parse("a = 1\nb = \"open\n").unwrap_err().line, 2);
        assert_eq!(Doc::parse("a = [1, [2]]\n").unwrap_err().line, 1);
        assert_eq!(Doc::parse("[t\n").unwrap_err().line, 1);
        assert_eq!(Doc::parse("a = wat\n").unwrap_err().line, 1);
        assert_eq!(Doc::parse("bad key = 1\n").unwrap_err().line, 1);
        assert_eq!(Doc::parse("a = 1 trailing\n").unwrap_err().line, 1);
    }

    #[test]
    fn trailing_comma_and_empty_array() {
        let doc = Doc::parse("a = [1, 2,]\nb = []\n").unwrap();
        assert_eq!(
            doc.tables[0].entries[0].value,
            Value::Array(vec![Value::Int(1), Value::Int(2)])
        );
        assert_eq!(doc.tables[0].entries[1].value, Value::Array(vec![]));
    }

    #[test]
    fn negative_and_float_forms() {
        let doc = Doc::parse("a = -3\nb = -0.5\nc = 1e3\n").unwrap();
        let e = &doc.tables[0].entries;
        assert_eq!(e[0].value, Value::Int(-3));
        assert_eq!(e[1].value, Value::Float(-0.5));
        assert_eq!(e[2].value, Value::Float(1000.0));
    }
}
