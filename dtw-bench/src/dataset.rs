//! Deterministic workload materialization.
//!
//! A [`crate::recipe::Recipe`] plus its seed fully determines every
//! series, query, stream sample and live-insert donor in a run: the
//! whole benchmark is a pure function of the recipe file. All series
//! are z-normalized by the generators, so indexes are built with
//! `znormalize(false)` and the bit-equality oracles see identical
//! floats on every path.

use dtw_bounds::data::rng::Rng;
use dtw_bounds::data::synthetic::{
    adversarial_warp_series, embed_stream, random_walk_series, sinusoid_pattern,
};
use dtw_bounds::data::znorm::znormalize;

use crate::recipe::{Family, QueryMix, Recipe};

/// Everything a scenario consumes, generated once per run.
pub struct BenchData {
    /// Indexed corpus.
    pub train: Vec<Vec<f64>>,
    /// Labels, round-robin over `classes`.
    pub labels: Vec<u32>,
    /// Query workload.
    pub queries: Vec<Vec<f64>>,
    /// Firehose samples (planted patterns from the head of `train`).
    pub stream: Vec<f64>,
    /// Fresh series the live scenario inserts.
    pub donors: Vec<Vec<f64>>,
}

fn draw(family: Family, rng: &mut Rng, len: usize) -> Vec<f64> {
    match family {
        Family::Sinusoid => sinusoid_pattern(rng, len),
        Family::RandomWalk => random_walk_series(rng, len),
        Family::Adversarial => adversarial_warp_series(rng, len),
    }
}

/// A near query: a corpus series under small amplitude jitter,
/// re-normalized so it stays on the unit sphere like everything else.
fn perturb(rng: &mut Rng, base: &[f64]) -> Vec<f64> {
    let mut out: Vec<f64> = base.iter().map(|&v| v + 0.08 * rng.normal()).collect();
    znormalize(&mut out);
    out
}

/// Generate the full workload for a recipe.
pub fn materialize(recipe: &Recipe) -> BenchData {
    let d = &recipe.dataset;
    let mut rng = Rng::seeded(recipe.seed);
    // Independent streams per component: adding queries can never shift
    // the corpus, and vice versa.
    let mut corpus_rng = rng.fork(1);
    let mut query_rng = rng.fork(2);
    let mut stream_rng = rng.fork(3);
    let mut donor_rng = rng.fork(4);

    let train: Vec<Vec<f64>> =
        (0..d.series).map(|_| draw(d.family, &mut corpus_rng, d.len)).collect();
    let labels: Vec<u32> = (0..d.series).map(|i| (i % d.classes) as u32).collect();

    let queries: Vec<Vec<f64>> = (0..recipe.queries.count)
        .map(|i| {
            let near = match recipe.queries.mix {
                QueryMix::Near => true,
                QueryMix::Fresh => false,
                QueryMix::Mixed => i % 2 == 0,
            };
            if near {
                let donor = query_rng.below(train.len());
                perturb(&mut query_rng, &train[donor])
            } else {
                draw(d.family, &mut query_rng, d.len)
            }
        })
        .collect();

    let pattern_count = train.len().min(8);
    let (stream, _planted) = embed_stream(
        &mut stream_rng,
        &train[..pattern_count],
        recipe.stream.samples,
        0.35,
        0.1,
        0.05,
    );

    let donors: Vec<Vec<f64>> =
        (0..recipe.live.inserts).map(|_| draw(d.family, &mut donor_rng, d.len)).collect();

    BenchData { train, labels, queries, stream, donors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recipe::{
        DatasetSpec, Grid, LiveSpec, OracleMode, QuerySpec, ScenarioKind, StreamSpec, WalMode,
    };

    fn recipe(seed: u64, mix: QueryMix) -> Recipe {
        Recipe {
            name: "data-unit".into(),
            description: String::new(),
            seed,
            dataset: DatasetSpec {
                family: Family::Sinusoid,
                series: 12,
                len: 24,
                window: 2,
                classes: 3,
            },
            queries: QuerySpec { count: 4, mix, k: 1 },
            grid: Grid { threads: vec![1], shards: vec![1], clusters: vec![0] },
            scenarios: vec![ScenarioKind::Knn],
            stream: StreamSpec { samples: 200, hop: 1, threshold: 10.0 },
            live: LiveSpec { inserts: 3, deletes: 1, wal: vec![WalMode::Off] },
            oracle: OracleMode::Brute,
        }
    }

    #[test]
    fn materialization_is_a_pure_function_of_the_recipe() {
        let a = materialize(&recipe(9, QueryMix::Mixed));
        let b = materialize(&recipe(9, QueryMix::Mixed));
        assert_eq!(a.train, b.train);
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.stream, b.stream);
        assert_eq!(a.donors, b.donors);
        let c = materialize(&recipe(10, QueryMix::Mixed));
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn query_mix_does_not_shift_the_corpus() {
        let a = materialize(&recipe(9, QueryMix::Near));
        let b = materialize(&recipe(9, QueryMix::Fresh));
        assert_eq!(a.train, b.train);
        assert_eq!(a.stream, b.stream);
        assert_ne!(a.queries, b.queries);
    }

    #[test]
    fn shapes_match_the_recipe() {
        let r = recipe(9, QueryMix::Mixed);
        let d = materialize(&r);
        assert_eq!(d.train.len(), r.dataset.series);
        assert!(d.train.iter().all(|s| s.len() == r.dataset.len));
        assert_eq!(d.labels, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0, 1, 2]);
        assert_eq!(d.queries.len(), r.queries.count);
        assert_eq!(d.stream.len(), r.stream.samples);
        assert_eq!(d.donors.len(), r.live.inserts);
    }
}
