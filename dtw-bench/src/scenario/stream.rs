//! Stream firehose: a threshold subsequence scan over the full grid.
//!
//! The scan runs with `znorm(false)` so window floats are bit-identical
//! to what the reference sweep sees (the rolling-moment z-normalizer is
//! deliberately *not* bit-equal to a rescan; the z-normalized stream
//! path keeps its own coverage in `rust/tests/stream.rs`). Matches
//! must be bit-equal to the reference at every grid point, and the
//! cascade must satisfy its stage-by-stage conservation chain.

use dtw_bounds::delta::Squared;
use dtw_bounds::stream::SubsequenceOptions;

use crate::runner::RunError;
use crate::scenario::{build_index, check_stream_conservation, stream_pairs, RunCtx};

/// Run the scenario.
pub fn run(ctx: &mut RunCtx) -> Result<(), RunError> {
    let spec = &ctx.recipe.stream;
    for point in ctx.recipe.grid.points() {
        let tag = point.tag();
        let index = build_index(ctx.data, ctx.recipe, point)?;
        let opts = SubsequenceOptions::threshold(spec.threshold)
            .with_hop(spec.hop)
            .with_znorm(false)
            .with_threads(point.threads);
        let report = index.subsequence_scan::<Squared>(&ctx.data.stream, opts)?;
        let context = format!("stream/{tag}");
        ctx.oracle.check_stream(&context, &stream_pairs(&report), &ctx.stream_truth)?;
        check_stream_conservation(&mut ctx.oracle, &context, &report, index.len())?;
        let windows = report.stats.windows.max(1) as f64;
        ctx.metric_lower("stream", &tag, "ns_per_window", report.busy.as_nanos() as f64 / windows, "ns");
        ctx.metric_higher("stream", &tag, "prune_rate", report.stats.prune_rate(), "ratio");
        // Deterministic counts: zero tolerance, so once a baseline is
        // recorded the gate flags any drift at all.
        ctx.metrics.push(
            crate::report::Metric::lower(
                format!("stream/{tag}/windows"),
                report.stats.windows as f64,
                "count",
            )
            .with_tolerance(0.0),
        );
        ctx.metrics.push(
            crate::report::Metric::lower(
                format!("stream/{tag}/matches"),
                report.stats.matches as f64,
                "count",
            )
            .with_tolerance(0.0),
        );
    }
    Ok(())
}
