//! Steady-state scalar k-NN over the full thread × shard × cluster
//! grid.
//!
//! Every grid point must reproduce the reference answers bit for bit
//! and satisfy the prune-counter conservation identity
//! `dtw_calls + pruned + cluster_members_pruned == n` on every query.

use std::time::Instant;

use dtw_bounds::index::query::QueryOptions;

use crate::runner::RunError;
use crate::scenario::{build_index, ns_since, pairs, RunCtx};

/// Run the scenario.
pub fn run(ctx: &mut RunCtx) -> Result<(), RunError> {
    let k = ctx.recipe.queries.k;
    for point in ctx.recipe.grid.points() {
        let tag = point.tag();
        let index = build_index(ctx.data, ctx.recipe, point)?;
        let mut searcher = index.searcher();
        let opts = QueryOptions::k(k);
        let mut total_ns = 0.0;
        let mut pruned_frac_sum = 0.0;
        for (qi, query) in ctx.data.queries.iter().enumerate() {
            let started = Instant::now();
            let outcome = searcher.query_values::<dtw_bounds::delta::Squared>(query, &opts);
            total_ns += ns_since(started);
            let context = format!("knn/{tag}/q{qi}");
            ctx.oracle.check_triples(&context, &pairs(&outcome), &ctx.knn_truth[qi])?;
            ctx.oracle.check_knn_conservation(&context, &outcome.stats, index.len())?;
            let candidates = index.len() as f64;
            pruned_frac_sum +=
                (outcome.stats.pruned + outcome.stats.cluster_members_pruned) as f64 / candidates;
        }
        let q = ctx.data.queries.len() as f64;
        ctx.metric_lower("knn", &tag, "ns_per_query", total_ns / q, "ns");
        ctx.metric_higher("knn", &tag, "prune_rate", pruned_frac_sum / q, "ratio");
    }
    Ok(())
}
