//! SIMD kernel microbenchmarks (the `kernel` scenario).
//!
//! For every ISA the host can dispatch to
//! ([`dtw_bounds::simd::available`]), times each vtable kernel over the
//! recipe's query × corpus workload and reports throughput as
//! `kernel/<isa>/<kernel>/cells_per_sec`. Before any timing, every
//! kernel is verified **bit-equal** to the scalar lane-protocol
//! reference over every (query, candidate) pair — the oracle fails the
//! run on the first diverging bit, so a throughput number can never be
//! reported for a kernel producing different answers.
//!
//! Cell counts are nominal (rows × ℓ): the early-abandoning variant is
//! credited with full rows even when it abandons, so its number reads
//! as *effective* throughput — abandoning earlier makes it larger.

use std::hint::black_box;
use std::time::Instant;

use dtw_bounds::bounds::PreparedSeries;
use dtw_bounds::simd::{self, Isa, Kernels};

use super::RunCtx;
use crate::report::Metric;
use crate::runner::RunError;

/// Cells each timing loop aims to stream: small enough for the tiny
/// unit-test recipe, large enough to out-run timer granularity.
const TARGET_CELLS: u64 = 400_000;

/// Per-sec throughput metric, generously toleranced (microbenchmarks
/// are the noisiest numbers in the report).
fn record(ctx: &mut RunCtx, isa: Isa, name: &str, cells: u64, start: Instant) {
    let secs = start.elapsed().as_secs_f64().max(1e-9);
    ctx.metrics.push(
        Metric::higher(
            format!("kernel/{isa}/{name}/cells_per_sec"),
            cells as f64 / secs,
            "cells/s",
        )
        .with_tolerance(0.5),
    );
}

pub fn run(ctx: &mut RunCtx) -> Result<(), RunError> {
    let w = ctx.recipe.dataset.window;
    let train: Vec<PreparedSeries> = ctx
        .data
        .train
        .iter()
        .map(|s| PreparedSeries::prepare(s.clone(), w))
        .collect();
    let queries: Vec<Vec<f64>> = ctx.data.queries.clone();
    let scalar = simd::for_isa(Isa::Scalar).expect("scalar kernels are always available");
    // Finite cuts (half the full scalar sum) so the early-abandoning
    // variant really abandons on a realistic fraction of the pairs.
    let cuts: Vec<Vec<f64>> = queries
        .iter()
        .map(|q| {
            train.iter().map(|t| 0.5 * (scalar.keogh_sq_sum)(q, &t.lo, &t.up)).collect()
        })
        .collect();

    for isa in simd::available() {
        let Some(k) = simd::for_isa(isa) else { continue };
        bench_isa(ctx, isa, k, scalar, &queries, &train, &cuts)?;
    }
    Ok(())
}

fn bench_isa(
    ctx: &mut RunCtx,
    isa: Isa,
    k: &'static Kernels,
    scalar: &'static Kernels,
    queries: &[Vec<f64>],
    train: &[PreparedSeries],
    cuts: &[Vec<f64>],
) -> Result<(), RunError> {
    let l = train.first().map(|t| t.values.len()).unwrap_or(0);
    let pair_cells = (queries.len() * train.len() * l) as u64;
    if pair_cells == 0 {
        return Ok(());
    }
    let rounds = (TARGET_CELLS / pair_cells).max(1);

    // --- summing kernels (full LB_Keogh rows) ---------------------------
    let sums: [(&str, fn(&Kernels) -> fn(&[f64], &[f64], &[f64]) -> f64); 2] =
        [("keogh_sq", |k| k.keogh_sq_sum), ("keogh_abs", |k| k.keogh_abs_sum)];
    for (name, get) in sums {
        let (kf, sf) = (get(k), get(scalar));
        for (qi, q) in queries.iter().enumerate() {
            for (ti, t) in train.iter().enumerate() {
                ctx.oracle.check_identity(
                    &format!("kernel/{isa}/{name}/q{qi}t{ti}"),
                    "bit-equal to scalar",
                    kf(q, &t.lo, &t.up).to_bits(),
                    sf(q, &t.lo, &t.up).to_bits(),
                )?;
            }
        }
        let start = Instant::now();
        let mut acc = 0.0;
        for _ in 0..rounds {
            for q in queries {
                for t in train {
                    acc += kf(q, &t.lo, &t.up);
                }
            }
        }
        black_box(acc);
        record(ctx, isa, name, rounds * pair_cells, start);
    }

    // --- early-abandoning sum -------------------------------------------
    {
        let name = "keogh_sq_ea";
        for (qi, q) in queries.iter().enumerate() {
            for (ti, t) in train.iter().enumerate() {
                let cut = cuts[qi][ti];
                ctx.oracle.check_identity(
                    &format!("kernel/{isa}/{name}/q{qi}t{ti}"),
                    "bit-equal to scalar",
                    (k.keogh_sq_ea)(q, &t.lo, &t.up, cut).to_bits(),
                    (scalar.keogh_sq_ea)(q, &t.lo, &t.up, cut).to_bits(),
                )?;
            }
        }
        let start = Instant::now();
        let mut acc = 0.0;
        for _ in 0..rounds {
            for (qi, q) in queries.iter().enumerate() {
                for (ti, t) in train.iter().enumerate() {
                    acc += (k.keogh_sq_ea)(q, &t.lo, &t.up, cuts[qi][ti]);
                }
            }
        }
        black_box(acc);
        record(ctx, isa, name, rounds * pair_cells, start);
    }

    // --- elementwise kernels --------------------------------------------
    let mut out_k = vec![0.0f64; l];
    let mut out_s = vec![0.0f64; l];

    {
        let name = "clamp";
        for (qi, q) in queries.iter().enumerate() {
            for (ti, t) in train.iter().enumerate() {
                (k.clamp)(q, &t.lo, &t.up, &mut out_k);
                (scalar.clamp)(q, &t.lo, &t.up, &mut out_s);
                let diverging = out_k
                    .iter()
                    .zip(out_s.iter())
                    .filter(|(a, b)| a.to_bits() != b.to_bits())
                    .count() as u64;
                ctx.oracle.check_identity(
                    &format!("kernel/{isa}/{name}/q{qi}t{ti}"),
                    "diverging lanes",
                    diverging,
                    0,
                )?;
            }
        }
        let start = Instant::now();
        for _ in 0..rounds {
            for q in queries {
                for t in train {
                    (k.clamp)(q, &t.lo, &t.up, &mut out_k);
                }
            }
        }
        black_box(&out_k);
        record(ctx, isa, name, rounds * pair_cells, start);
    }

    if l > 1 {
        let name = "pair_min";
        for (ti, t) in train.iter().enumerate() {
            (k.pair_min)(&t.values, &mut out_k[..l - 1]);
            (scalar.pair_min)(&t.values, &mut out_s[..l - 1]);
            let diverging = out_k[..l - 1]
                .iter()
                .zip(out_s[..l - 1].iter())
                .filter(|(a, b)| a.to_bits() != b.to_bits())
                .count() as u64;
            ctx.oracle.check_identity(
                &format!("kernel/{isa}/{name}/t{ti}"),
                "diverging lanes",
                diverging,
                0,
            )?;
        }
        let per_round = (train.len() * (l - 1)) as u64;
        let rounds = (TARGET_CELLS / per_round).max(1);
        let start = Instant::now();
        for _ in 0..rounds {
            for t in train {
                (k.pair_min)(&t.values, &mut out_k[..l - 1]);
            }
        }
        black_box(&out_k);
        record(ctx, isa, name, rounds * per_round, start);
    }

    {
        let name = "min_merge";
        for (ti, t) in train.iter().enumerate() {
            out_k.copy_from_slice(&t.lo);
            out_s.copy_from_slice(&t.lo);
            (k.min_merge)(&mut out_k, &t.up);
            (scalar.min_merge)(&mut out_s, &t.up);
            let diverging = out_k
                .iter()
                .zip(out_s.iter())
                .filter(|(a, b)| a.to_bits() != b.to_bits())
                .count() as u64;
            ctx.oracle.check_identity(
                &format!("kernel/{isa}/{name}/t{ti}"),
                "diverging lanes",
                diverging,
                0,
            )?;
        }
        let per_round = (train.len() * l) as u64;
        let rounds = (TARGET_CELLS / per_round).max(1);
        let start = Instant::now();
        for _ in 0..rounds {
            for t in train {
                (k.min_merge)(&mut out_k, &t.lo);
            }
        }
        black_box(&out_k);
        record(ctx, isa, name, rounds * per_round, start);
    }

    Ok(())
}
