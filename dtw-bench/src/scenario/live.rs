//! Mixed query + stream over live mutation.
//!
//! Drives an [`NnEngine`] through a deterministic interleaving of
//! inserts, deletes and queries while a plain mirror of the logical
//! row set is kept on the side. At every checkpoint the engine's
//! answers (scalar k-NN, and the subsequence scan) must be bit-equal
//! to a **cold rebuild** of the mirror — the acceptance contract for
//! the whole live subsystem — and every live query must satisfy the
//! delta-shard conservation identity. A final compaction is timed and
//! re-verified the same way.
//!
//! The whole scenario runs once per `[live] wal` mode. `wal-off` is the
//! bare in-memory path; `wal-always` anchors the engine to a real
//! on-disk snapshot path and enables `FsyncPolicy::Always`, so the
//! timed mutation acks (and the final durable compaction) include the
//! write-ahead append + fsync — the off/always delta on `insert_ns` is
//! the per-mutation durability tax.

use std::time::Instant;

use dtw_bounds::coordinator::NnEngine;
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::index::query::QueryOptions;
use dtw_bounds::index::DtwIndex;
use dtw_bounds::live::FsyncPolicy;
use dtw_bounds::stream::SubsequenceOptions;

use crate::recipe::WalMode;
use crate::runner::RunError;
use crate::scenario::{build_index, ns_since, pairs, stream_pairs, RunCtx};

/// The logical row set the engine is expected to serve.
struct Mirror {
    rows: Vec<(Vec<f64>, u32)>,
    window: usize,
    threads: usize,
    shards: usize,
    clusters: usize,
}

impl Mirror {
    /// Cold rebuild: a fresh index over exactly the logical rows, with
    /// shard/cluster counts clamped to the shrinking row count.
    fn build(&self) -> Result<DtwIndex, RunError> {
        let series: Vec<Vec<f64>> = self.rows.iter().map(|(s, _)| s.clone()).collect();
        let labels: Vec<u32> = self.rows.iter().map(|&(_, l)| l).collect();
        let mut b = DtwIndex::builder(series)
            .labels(labels)
            .window(self.window)
            .znormalize(false)
            .threads(self.threads)
            .shards(self.shards.min(self.rows.len()).max(1));
        if self.clusters > 0 {
            b = b.clusters(self.clusters.min(self.rows.len()));
        }
        b.build().map_err(RunError::Other)
    }
}

/// One checkpoint: a live query must satisfy delta conservation and
/// match a cold rebuild bit for bit. Returns the query's latency in ns.
fn verify_checkpoint(
    ctx: &mut RunCtx,
    engine: &mut NnEngine,
    mirror: &Mirror,
    tag: &str,
    checkpoint: usize,
) -> Result<f64, RunError> {
    let k = ctx.recipe.queries.k;
    let qi = checkpoint % ctx.data.queries.len();
    let query = &ctx.data.queries[qi];
    let started = Instant::now();
    let outcome = engine.query_with(query, &QueryOptions::k(k));
    let elapsed = ns_since(started);
    let context = format!("live/{tag}/check{checkpoint}/q{qi}");
    ctx.oracle.check_delta_conservation(&context, &outcome.stats)?;
    let cold = mirror.build()?;
    let truth = cold.knn::<Squared>(query, k);
    ctx.oracle.check_triples(&context, &pairs(&outcome), &pairs(&truth))?;
    Ok(elapsed)
}

fn stream_opts(ctx: &RunCtx, threads: usize) -> SubsequenceOptions {
    SubsequenceOptions::threshold(ctx.recipe.stream.threshold)
        .with_hop(ctx.recipe.stream.hop)
        .with_znorm(false)
        .with_threads(threads)
}

/// Run the scenario, once per `[live] wal` mode.
pub fn run(ctx: &mut RunCtx) -> Result<(), RunError> {
    for mode in ctx.recipe.live.wal.clone() {
        run_mode(ctx, mode)?;
    }
    Ok(())
}

/// One full mutation/verification pass under one durability mode.
fn run_mode(ctx: &mut RunCtx, mode: WalMode) -> Result<(), RunError> {
    let point = ctx.recipe.grid.representative_point();
    let tag = format!("{}.wal-{}", point.tag(), mode.name());
    let k = ctx.recipe.queries.k;
    let classes = ctx.recipe.dataset.classes;
    let spec = ctx.recipe.live.clone();

    let mut engine = NnEngine::from_index(build_index(ctx.data, ctx.recipe, point)?);
    // wal-always pins the engine to a real on-disk anchor so every
    // timed mutation ack below includes the write-ahead append + fsync
    // a durable server pays before answering, and the final compaction
    // includes the durable log rotation.
    let wal_dir = match mode {
        WalMode::Off => None,
        WalMode::Always => {
            let dir = std::env::temp_dir()
                .join(format!("dtw-bench-wal-{}-{tag}", std::process::id()));
            // A stale dir (crashed earlier run, recycled pid) would
            // hand enable_wal a log to replay — start from nothing.
            std::fs::remove_dir_all(&dir).ok();
            std::fs::create_dir_all(&dir).map_err(|e| RunError::Other(e.into()))?;
            engine
                .enable_wal(&dir.join("live.snap"), FsyncPolicy::Always)
                .map_err(RunError::Other)?;
            Some(dir)
        }
    };
    let mut mirror = Mirror {
        rows: ctx
            .data
            .train
            .iter()
            .cloned()
            .zip(ctx.data.labels.iter().copied())
            .collect(),
        window: ctx.recipe.dataset.window,
        threads: point.threads,
        shards: point.shards,
        clusters: point.clusters,
    };

    // The corpus never shrinks below this, so k-NN stays well-defined.
    let min_rows = (k + 1).max(2);
    let mut rng = Rng::seeded(ctx.recipe.seed ^ 0x11FE_C0DE);
    let mut ops: Vec<bool> = Vec::with_capacity(spec.inserts + spec.deletes);
    ops.extend(std::iter::repeat(true).take(spec.inserts));
    ops.extend(std::iter::repeat(false).take(spec.deletes));
    rng.shuffle(&mut ops);

    let check_every = (ops.len() / 4).max(1);
    let mut donors = ctx.data.donors.iter();
    let mut insert_ns = 0.0;
    let mut delete_ns = 0.0;
    let mut query_ns = 0.0;
    let mut queries_run = 0usize;
    let mut checkpoint = 0usize;

    for (op_idx, &is_insert) in ops.iter().enumerate() {
        if is_insert {
            let values = donors.next().expect("donor count == spec.inserts").clone();
            let label = (mirror.rows.len() % classes) as u32;
            let started = Instant::now();
            engine.insert(label, values.clone())?;
            insert_ns += ns_since(started);
            mirror.rows.push((values, label));
        } else if mirror.rows.len() > min_rows {
            let id = rng.below(mirror.rows.len());
            let started = Instant::now();
            engine.delete(id)?;
            delete_ns += ns_since(started);
            mirror.rows.remove(id);
        }
        if (op_idx + 1) % check_every == 0 {
            query_ns += verify_checkpoint(ctx, &mut engine, &mirror, &tag, checkpoint)?;
            queries_run += 1;
            checkpoint += 1;
        }
    }

    // Stream over the live (delta-bearing) state vs. the cold rebuild.
    let live_report = engine.query_stream(&ctx.data.stream, stream_opts(ctx, point.threads))?;
    let cold = mirror.build()?;
    let cold_report =
        cold.subsequence_scan::<Squared>(&ctx.data.stream, stream_opts(ctx, point.threads))?;
    ctx.oracle.check_stream(
        &format!("live/{tag}/stream-delta"),
        &stream_pairs(&live_report),
        &stream_pairs(&cold_report),
    )?;

    let delta_len = engine.delta_len();
    let started = Instant::now();
    engine.compact()?;
    let compact_ns = ns_since(started);
    query_ns += verify_checkpoint(ctx, &mut engine, &mirror, &tag, checkpoint)?;
    queries_run += 1;
    let compacted_report =
        engine.query_stream(&ctx.data.stream, stream_opts(ctx, point.threads))?;
    ctx.oracle.check_stream(
        &format!("live/{tag}/stream-compacted"),
        &stream_pairs(&compacted_report),
        &stream_pairs(&cold_report),
    )?;

    let inserts = spec.inserts.max(1) as f64;
    let deletes = spec.deletes.max(1) as f64;
    ctx.metric_lower("live", &tag, "insert_ns", insert_ns / inserts, "ns");
    ctx.metric_lower("live", &tag, "delete_ns", delete_ns / deletes, "ns");
    ctx.metric_lower("live", &tag, "query_ns", query_ns / queries_run.max(1) as f64, "ns");
    ctx.metric_lower("live", &tag, "compact_ns", compact_ns, "ns");
    ctx.metric_lower("live", &tag, "delta_len_at_compact", delta_len as f64, "count");
    if let Some(dir) = wal_dir {
        std::fs::remove_dir_all(&dir).ok();
    }
    Ok(())
}
