//! Batched screening: the whole query set goes through
//! `Searcher::query_batch` with the `SortedPrecomputed` strategy, so
//! the batch prefilter path (when it engages) is exercised against the
//! same reference answers as the scalar path.
//!
//! Whether a given grid point actually routes through the batched
//! prefilter is backend- and shape-dependent, so the scenario records
//! the batched fraction as a metric instead of asserting it; answer
//! bit-equality and conservation are asserted unconditionally.

use std::time::Instant;

use dtw_bounds::delta::Squared;
use dtw_bounds::index::query::QueryOptions;
use dtw_bounds::search::SearchStrategy;

use crate::runner::RunError;
use crate::scenario::{build_index, ns_since, pairs, RunCtx};

/// Run the scenario.
pub fn run(ctx: &mut RunCtx) -> Result<(), RunError> {
    let k = ctx.recipe.queries.k;
    for point in ctx.recipe.grid.points() {
        let tag = point.tag();
        let index = build_index(ctx.data, ctx.recipe, point)?
            .with_strategy(SearchStrategy::SortedPrecomputed);
        let mut searcher = index.searcher();
        let opts = QueryOptions::k(k);
        let started = Instant::now();
        let outcomes = searcher.query_batch::<Squared>(&ctx.data.queries, &opts);
        let total_ns = ns_since(started);
        let mut batched = 0usize;
        for (qi, outcome) in outcomes.iter().enumerate() {
            let context = format!("batched/{tag}/q{qi}");
            ctx.oracle.check_triples(&context, &pairs(outcome), &ctx.knn_truth[qi])?;
            ctx.oracle.check_knn_conservation(&context, &outcome.stats, index.len())?;
            if outcome.batched {
                batched += 1;
            }
        }
        let q = ctx.data.queries.len() as f64;
        ctx.metric_lower("batched", &tag, "ns_per_query", total_ns / q, "ns");
        ctx.metric_higher("batched", &tag, "batched_fraction", batched as f64 / q, "ratio");
    }
    Ok(())
}
