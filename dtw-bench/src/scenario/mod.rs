//! The benchmark scenarios.
//!
//! Each scenario is a free function over the shared [`RunCtx`]
//! (recipe, materialized data, precomputed reference answers, the
//! oracle, and the metric sink). Scenarios measure *and* verify: every
//! timed operation's results pass through the exactness oracles before
//! its timing is recorded, so a metric can never be reported for a
//! run that produced wrong answers.

pub mod batched;
pub mod cold_start;
pub mod kernel;
pub mod knn;
pub mod live;
pub mod snapshot;
pub mod stream;

use std::time::Instant;

use dtw_bounds::index::query::QueryOutcome;
use dtw_bounds::index::DtwIndex;
use dtw_bounds::stream::StreamReport;

use crate::dataset::BenchData;
use crate::oracle::{Oracle, StreamTriple, Triple};
use crate::recipe::{GridPoint, Recipe};
use crate::report::Metric;
use crate::runner::RunError;

/// Everything a scenario reads and writes.
pub struct RunCtx<'a> {
    /// The recipe being run.
    pub recipe: &'a Recipe,
    /// The materialized workload.
    pub data: &'a BenchData,
    /// Reference k-NN answers, one list per query.
    pub knn_truth: Vec<Vec<Triple>>,
    /// Reference stream matches.
    pub stream_truth: Vec<StreamTriple>,
    /// Assertion counter + failure reporting.
    pub oracle: Oracle,
    /// Metric sink (flat, emitted into the report at the end).
    pub metrics: Vec<Metric>,
}

impl RunCtx<'_> {
    /// Record a lower-is-better metric under `scenario/tag/name`.
    pub fn metric_lower(&mut self, scenario: &str, tag: &str, name: &str, value: f64, unit: &str) {
        self.metrics.push(Metric::lower(format!("{scenario}/{tag}/{name}"), value, unit));
    }

    /// Record a higher-is-better metric under `scenario/tag/name`.
    pub fn metric_higher(&mut self, scenario: &str, tag: &str, name: &str, value: f64, unit: &str) {
        self.metrics.push(Metric::higher(format!("{scenario}/{tag}/{name}"), value, unit));
    }
}

/// Build an index over the corpus at one grid point. All bench indexes
/// are built with `znormalize(false)`: the generators already
/// normalized every series, and skipping the index's own pass keeps
/// the floats bit-identical to what the reference kernels see.
pub fn build_index(data: &BenchData, recipe: &Recipe, point: GridPoint) -> Result<DtwIndex, RunError> {
    let mut b = DtwIndex::builder(data.train.clone())
        .labels(data.labels.clone())
        .window(recipe.dataset.window)
        .znormalize(false)
        .threads(point.threads)
        .shards(point.shards);
    if point.clusters > 0 {
        b = b.clusters(point.clusters);
    }
    b.build().map_err(RunError::Other)
}

/// Flatten a query outcome into the oracle's comparison triples.
pub fn pairs(outcome: &QueryOutcome) -> Vec<Triple> {
    outcome.neighbors.iter().map(|n| (n.index, n.label, n.distance)).collect()
}

/// Flatten a stream report into the oracle's comparison quadruples.
pub fn stream_pairs(report: &StreamReport) -> Vec<StreamTriple> {
    report.matches.iter().map(|m| (m.start, m.neighbor, m.label, m.distance)).collect()
}

/// Nanoseconds elapsed since `start`, as a metric value.
pub fn ns_since(start: Instant) -> f64 {
    start.elapsed().as_nanos() as f64
}

/// Verify the stream cascade's conservation chain on a frozen index:
/// every candidate enters stage 0 (minus cluster-pruned members), each
/// stage hands its survivors to the next, and the survivors of the
/// last stage are exactly the DTW calls.
pub fn check_stream_conservation(
    oracle: &mut Oracle,
    context: &str,
    report: &StreamReport,
    n: usize,
) -> Result<(), RunError> {
    let s = &report.stats;
    oracle.check_identity(context, "candidates == windows * n", s.candidates, s.windows * n as u64)?;
    let mut expect = s.candidates - s.cluster_members_pruned;
    for (i, stage) in s.stages.iter().enumerate() {
        oracle.check_identity(context, &format!("stage {i} lb_calls"), stage.lb_calls, expect)?;
        expect = stage.lb_calls - stage.pruned;
    }
    oracle.check_identity(context, "dtw_calls == last-stage survivors", s.dtw_calls, expect)?;
    Ok(())
}
