//! Cold start: serving the first query after a restart, both ways.
//!
//! Measures build-from-raw vs. snapshot-load at the representative
//! grid point, then asserts both cold paths answer the first query
//! bit-identically to the reference before their timings are recorded.

use std::fs;
use std::time::Instant;

use dtw_bounds::delta::Squared;
use dtw_bounds::index::DtwIndex;

use crate::runner::RunError;
use crate::scenario::{build_index, ns_since, pairs, RunCtx};

/// Run the scenario.
pub fn run(ctx: &mut RunCtx) -> Result<(), RunError> {
    let point = ctx.recipe.grid.representative_point();
    let tag = point.tag();
    let k = ctx.recipe.queries.k;
    let query = &ctx.data.queries[0];

    // Path A: rebuild from raw series, then serve.
    let started = Instant::now();
    let built = build_index(ctx.data, ctx.recipe, point)?;
    let build_ns = ns_since(started);
    let started = Instant::now();
    let outcome = built.knn::<Squared>(query, k);
    let first_query_build_ns = ns_since(started);
    ctx.oracle.check_triples(
        &format!("cold-start/{tag}/built"),
        &pairs(&outcome),
        &ctx.knn_truth[0],
    )?;

    // Path B: load a snapshot, then serve.
    let path = std::env::temp_dir().join(format!("dtw-bench-{}-cold.idx", std::process::id()));
    let bytes = built
        .save(&path)
        .map_err(|e| RunError::Other(anyhow::anyhow!("cold-start snapshot save: {e}")))?;
    let started = Instant::now();
    let loaded = DtwIndex::load(&path)
        .map_err(|e| RunError::Other(anyhow::anyhow!("cold-start snapshot load: {e}")));
    let load_ns = ns_since(started);
    let _ = fs::remove_file(&path);
    let loaded = loaded?;
    let started = Instant::now();
    let outcome = loaded.knn::<Squared>(query, k);
    let first_query_load_ns = ns_since(started);
    ctx.oracle.check_triples(
        &format!("cold-start/{tag}/loaded"),
        &pairs(&outcome),
        &ctx.knn_truth[0],
    )?;

    ctx.metric_lower("cold-start", &tag, "build_ns", build_ns, "ns");
    ctx.metric_lower("cold-start", &tag, "load_ns", load_ns, "ns");
    ctx.metric_lower("cold-start", &tag, "first_query_build_ns", first_query_build_ns, "ns");
    ctx.metric_lower("cold-start", &tag, "first_query_load_ns", first_query_load_ns, "ns");
    ctx.metric_lower("cold-start", &tag, "snapshot_bytes", bytes as f64, "bytes");
    Ok(())
}
