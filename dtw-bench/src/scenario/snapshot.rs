//! Snapshot integrity: a save/load round trip must preserve answers
//! exactly.
//!
//! Unlike `cold-start` (which times the restart paths on one query),
//! this scenario replays the **entire** query workload against the
//! reloaded index and holds every answer to bit-equality with the
//! reference, plus conservation on every query.

use std::fs;
use std::time::Instant;

use dtw_bounds::delta::Squared;
use dtw_bounds::index::query::QueryOptions;
use dtw_bounds::index::DtwIndex;

use crate::runner::RunError;
use crate::scenario::{build_index, ns_since, pairs, RunCtx};

/// Run the scenario.
pub fn run(ctx: &mut RunCtx) -> Result<(), RunError> {
    let point = ctx.recipe.grid.representative_point();
    let tag = point.tag();
    let index = build_index(ctx.data, ctx.recipe, point)?;

    let path = std::env::temp_dir().join(format!("dtw-bench-{}-snap.idx", std::process::id()));
    let started = Instant::now();
    let save = index.save(&path);
    let save_ns = ns_since(started);
    let bytes = match save {
        Ok(b) => b,
        Err(e) => {
            let _ = fs::remove_file(&path);
            return Err(RunError::Other(anyhow::anyhow!("snapshot save: {e}")));
        }
    };
    let started = Instant::now();
    let loaded = DtwIndex::load(&path);
    let load_ns = ns_since(started);
    let _ = fs::remove_file(&path);
    let loaded =
        loaded.map_err(|e| RunError::Other(anyhow::anyhow!("snapshot load: {e}")))?;

    let mut searcher = loaded.searcher();
    let opts = QueryOptions::k(ctx.recipe.queries.k);
    for (qi, query) in ctx.data.queries.iter().enumerate() {
        let outcome = searcher.query_values::<Squared>(query, &opts);
        let context = format!("snapshot/{tag}/q{qi}");
        ctx.oracle.check_triples(&context, &pairs(&outcome), &ctx.knn_truth[qi])?;
        ctx.oracle.check_knn_conservation(&context, &outcome.stats, loaded.len())?;
    }

    ctx.metric_lower("snapshot", &tag, "save_ns", save_ns, "ns");
    ctx.metric_lower("snapshot", &tag, "load_ns", load_ns, "ns");
    ctx.metric_lower("snapshot", &tag, "bytes", bytes as f64, "bytes");
    Ok(())
}
