//! The schema-versioned bench report.
//!
//! One run emits one `bench-report.json` at the workspace root. The
//! format replaces the seven per-bench `BENCH_*.json` emitters the
//! figure drivers used to carry: every metric is a single line with a
//! stable id (`scenario/t1.s2.c4/metric`), a value, a unit, a
//! direction, and an optional per-metric regression tolerance. The
//! emitter writes one metric per line precisely so the parser (and the
//! regression gate, and a human in a diff) can read it line-by-line
//! without a JSON library — the same hand-rolled discipline as the
//! recipe parser.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Bumped whenever a field is added, removed, or re-interpreted.
pub const SCHEMA_VERSION: u64 = 1;

/// One measured (or counted) value.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    /// Stable id: `scenario/<grid tag>/<name>`.
    pub id: String,
    /// The value.
    pub value: f64,
    /// Unit (`ns`, `ratio`, `count`, `bytes`).
    pub unit: String,
    /// Direction for the regression gate.
    pub higher_is_better: bool,
    /// Per-metric relative tolerance override; `None` uses the gate
    /// default.
    pub tolerance: Option<f64>,
}

impl Metric {
    /// A lower-is-better metric with the default tolerance.
    pub fn lower(id: impl Into<String>, value: f64, unit: &str) -> Metric {
        Metric {
            id: id.into(),
            value,
            unit: unit.to_string(),
            higher_is_better: false,
            tolerance: None,
        }
    }

    /// A higher-is-better metric with the default tolerance.
    pub fn higher(id: impl Into<String>, value: f64, unit: &str) -> Metric {
        Metric {
            id: id.into(),
            value,
            unit: unit.to_string(),
            higher_is_better: true,
            tolerance: None,
        }
    }

    /// Set a per-metric relative tolerance (e.g. `0.5` = ±50%).
    pub fn with_tolerance(mut self, tolerance: f64) -> Metric {
        self.tolerance = Some(tolerance);
        self
    }
}

/// One full run: provenance plus the flat metric list.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    /// [`SCHEMA_VERSION`] at emit time.
    pub schema_version: u64,
    /// Recipe name.
    pub recipe: String,
    /// Recipe seed (the run is a pure function of recipe + seed).
    pub seed: u64,
    /// Oracle mode name (`brute` | `cross`).
    pub oracle_mode: String,
    /// Total oracle assertions that passed.
    pub oracle_checks: u64,
    /// SIMD ISA the run dispatched to (`scalar` | `sse2` | `avx2` |
    /// `neon`). Informational provenance: results are bit-identical
    /// across ISAs, but ns/op metrics are only comparable within one.
    /// Absent in pre-SIMD reports; the tolerant parser defaults it to
    /// the empty string, so no schema bump.
    pub isa: String,
    /// Scenario names that ran, in order.
    pub scenarios: Vec<String>,
    /// All metrics, in emit order.
    pub metrics: Vec<Metric>,
}

/// Format an f64 so the JSON round-trips exactly: integral values keep
/// one decimal (so they stay floats), everything else uses the shortest
/// form `f64::to_string` produces (which Rust guarantees re-parses to
/// the same bits).
pub fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{v:.1}")
    } else {
        format!("{v}")
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl Report {
    /// Serialize to the canonical one-metric-per-line JSON form.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        let _ = writeln!(out, "  \"schema_version\": {},", self.schema_version);
        let _ = writeln!(out, "  \"recipe\": \"{}\",", esc(&self.recipe));
        let _ = writeln!(out, "  \"seed\": {},", self.seed);
        let _ = writeln!(out, "  \"oracle_mode\": \"{}\",", esc(&self.oracle_mode));
        let _ = writeln!(out, "  \"oracle_checks\": {},", self.oracle_checks);
        let _ = writeln!(out, "  \"isa\": \"{}\",", esc(&self.isa));
        let scenarios: Vec<String> =
            self.scenarios.iter().map(|s| format!("\"{}\"", esc(s))).collect();
        let _ = writeln!(out, "  \"scenarios\": [{}],", scenarios.join(", "));
        out.push_str("  \"metrics\": [\n");
        for (i, m) in self.metrics.iter().enumerate() {
            let tol = match m.tolerance {
                Some(t) => format!(", \"tolerance\": {}", fmt_f64(t)),
                None => String::new(),
            };
            let comma = if i + 1 == self.metrics.len() { "" } else { "," };
            let _ = writeln!(
                out,
                "    {{\"id\": \"{}\", \"value\": {}, \"unit\": \"{}\", \"higher_is_better\": {}{}}}{}",
                esc(&m.id),
                fmt_f64(m.value),
                esc(&m.unit),
                m.higher_is_better,
                tol,
                comma
            );
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse the canonical form back. Line-oriented on purpose: each
    /// metric lives on one line, headers are `"key": value` lines.
    pub fn parse(text: &str) -> Result<Report, String> {
        let mut report = Report {
            schema_version: 0,
            recipe: String::new(),
            seed: 0,
            oracle_mode: String::new(),
            oracle_checks: 0,
            isa: String::new(),
            scenarios: Vec::new(),
            metrics: Vec::new(),
        };
        for line in text.lines() {
            let t = line.trim().trim_end_matches(',');
            if let Some(v) = num_field(t, "schema_version") {
                report.schema_version = v as u64;
            } else if let Some(v) = str_field(t, "recipe") {
                report.recipe = v;
            } else if let Some(v) = num_field(t, "seed") {
                report.seed = v as u64;
            } else if let Some(v) = str_field(t, "oracle_mode") {
                report.oracle_mode = v;
            } else if let Some(v) = num_field(t, "oracle_checks") {
                report.oracle_checks = v as u64;
            } else if let Some(v) = str_field(t, "isa") {
                report.isa = v;
            } else if t.starts_with("\"scenarios\"") {
                let body = t
                    .split_once('[')
                    .and_then(|(_, rest)| rest.rsplit_once(']'))
                    .map(|(inner, _)| inner)
                    .ok_or_else(|| format!("malformed scenarios line: {t}"))?;
                report.scenarios = body
                    .split(',')
                    .map(|p| p.trim().trim_matches('"').to_string())
                    .filter(|p| !p.is_empty())
                    .collect();
            } else if t.starts_with("{\"id\"") {
                report.metrics.push(parse_metric(t)?);
            }
        }
        if report.schema_version == 0 {
            return Err("missing schema_version".to_string());
        }
        if report.schema_version != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {} unsupported (this build reads {})",
                report.schema_version, SCHEMA_VERSION
            ));
        }
        Ok(report)
    }

    /// Look up a metric by id.
    pub fn metric(&self, id: &str) -> Option<&Metric> {
        self.metrics.iter().find(|m| m.id == id)
    }

    /// Write the report to `path`.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        fs::write(path, self.to_json())
    }

    /// Read and parse a report from `path`.
    pub fn load(path: &Path) -> Result<Report, String> {
        let text = fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Report::parse(&text)
    }
}

/// Extract `"key": 123` / `"key": 1.5`.
fn num_field(line: &str, key: &str) -> Option<f64> {
    let rest = line.strip_prefix(&format!("\"{key}\""))?;
    let rest = rest.trim_start().strip_prefix(':')?.trim();
    rest.parse::<f64>().ok()
}

/// Extract `"key": "value"`.
fn str_field(line: &str, key: &str) -> Option<String> {
    let rest = line.strip_prefix(&format!("\"{key}\""))?;
    let rest = rest.trim_start().strip_prefix(':')?.trim();
    let rest = rest.strip_prefix('"')?.strip_suffix('"')?;
    Some(rest.replace("\\\"", "\"").replace("\\\\", "\\"))
}

/// Parse one `{"id": ..., "value": ..., ...}` metric line.
fn parse_metric(line: &str) -> Result<Metric, String> {
    let body = line
        .strip_prefix('{')
        .and_then(|s| s.strip_suffix('}'))
        .ok_or_else(|| format!("malformed metric line: {line}"))?;
    let mut id = None;
    let mut value = None;
    let mut unit = None;
    let mut higher = None;
    let mut tolerance = None;
    for piece in split_fields(body) {
        let piece = piece.trim();
        if let Some(v) = str_field(piece, "id") {
            id = Some(v);
        } else if let Some(v) = num_field(piece, "value") {
            value = Some(v);
        } else if let Some(v) = str_field(piece, "unit") {
            unit = Some(v);
        } else if let Some(rest) = piece.strip_prefix("\"higher_is_better\"") {
            match rest.trim_start().strip_prefix(':').map(str::trim) {
                Some("true") => higher = Some(true),
                Some("false") => higher = Some(false),
                _ => return Err(format!("malformed higher_is_better in: {line}")),
            }
        } else if let Some(v) = num_field(piece, "tolerance") {
            tolerance = Some(v);
        } else if !piece.is_empty() {
            return Err(format!("unknown metric field `{piece}` in: {line}"));
        }
    }
    Ok(Metric {
        id: id.ok_or_else(|| format!("metric missing id: {line}"))?,
        value: value.ok_or_else(|| format!("metric missing value: {line}"))?,
        unit: unit.ok_or_else(|| format!("metric missing unit: {line}"))?,
        higher_is_better: higher
            .ok_or_else(|| format!("metric missing higher_is_better: {line}"))?,
        tolerance,
    })
}

/// Split a metric body on top-level commas (commas inside quoted ids
/// are inert).
fn split_fields(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else if c == '"' {
            in_str = true;
        } else if c == ',' {
            out.push(&s[start..i]);
            start = i + 1;
        }
    }
    out.push(&s[start..]);
    out
}

/// The one place path layout is decided: the workspace root is this
/// crate's parent directory. Reports land at `<root>/bench-report.json`
/// and the checked-in baseline at `<root>/dtw-bench/baseline.json` —
/// callers never consult `CARGO_MANIFEST_DIR` themselves.
pub fn workspace_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("dtw-bench lives one level below the workspace root")
        .to_path_buf()
}

/// Default report output path.
pub fn default_report_path() -> PathBuf {
    workspace_root().join("bench-report.json")
}

/// Checked-in baseline path.
pub fn default_baseline_path() -> PathBuf {
    workspace_root().join("dtw-bench").join("baseline.json")
}

/// Recipes directory.
pub fn recipes_dir() -> PathBuf {
    workspace_root().join("dtw-bench").join("recipes")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            schema_version: SCHEMA_VERSION,
            recipe: "quick".into(),
            seed: 77,
            oracle_mode: "brute".into(),
            oracle_checks: 420,
            isa: "avx2".into(),
            scenarios: vec!["knn".into(), "stream".into()],
            metrics: vec![
                Metric::lower("knn/t1.s1.c0/ns_per_query", 12345.0, "ns"),
                Metric::higher("knn/t1.s1.c0/prune_rate", 0.8125, "ratio")
                    .with_tolerance(0.5),
                Metric::lower("snapshot/t2.s2.c4/bytes", 65536.0, "bytes"),
            ],
        }
    }

    #[test]
    fn json_round_trips_every_field() {
        let r = sample();
        let parsed = Report::parse(&r.to_json()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn value_formatting_round_trips_bits() {
        for v in [0.1 + 0.2, 1.0 / 3.0, 12345.0, 0.0, 1e-9] {
            let s = fmt_f64(v);
            assert_eq!(s.parse::<f64>().unwrap().to_bits(), v.to_bits(), "via {s}");
        }
    }

    #[test]
    fn future_schema_versions_are_rejected() {
        let text = sample().to_json().replace(
            &format!("\"schema_version\": {SCHEMA_VERSION}"),
            "\"schema_version\": 999",
        );
        let e = Report::parse(&text).unwrap_err();
        assert!(e.contains("999"), "{e}");
    }

    #[test]
    fn metric_lookup_and_tolerance_survive() {
        let r = Report::parse(&sample().to_json()).unwrap();
        let m = r.metric("knn/t1.s1.c0/prune_rate").unwrap();
        assert_eq!(m.tolerance, Some(0.5));
        assert!(m.higher_is_better);
        assert!(r.metric("nope").is_none());
    }
}
