//! The run loop: materialize the workload, compute the reference
//! answers once, dispatch the recipe's scenarios, and assemble the
//! schema-versioned report.

use dtw_bounds::delta::Squared;

use crate::dataset::{materialize, BenchData};
use crate::oracle::{reference_knn, reference_stream, Oracle, OracleError, StreamTriple, Triple};
use crate::recipe::{Grid, OracleMode, Recipe, ScenarioKind};
use crate::report::{Report, SCHEMA_VERSION};
use crate::scenario::{self, build_index, check_stream_conservation, pairs, stream_pairs, RunCtx};

/// Why a run stopped.
#[derive(Debug)]
pub enum RunError {
    /// An exactness oracle tripped — the engine produced a wrong
    /// answer. Always fatal, never warn-only.
    Oracle(OracleError),
    /// Infrastructure failure (build, I/O, snapshot).
    Other(anyhow::Error),
}

impl From<OracleError> for RunError {
    fn from(e: OracleError) -> RunError {
        RunError::Oracle(e)
    }
}

impl From<anyhow::Error> for RunError {
    fn from(e: anyhow::Error) -> RunError {
        RunError::Other(e)
    }
}

impl std::fmt::Display for RunError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunError::Oracle(e) => write!(f, "oracle failure: {e}"),
            RunError::Other(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RunError {}

/// Reference k-NN answers for every query, per the recipe's oracle
/// mode.
fn knn_truth(recipe: &Recipe, data: &BenchData, oracle: &mut Oracle) -> Result<Vec<Vec<Triple>>, RunError> {
    match recipe.oracle {
        OracleMode::Brute => Ok(data
            .queries
            .iter()
            .map(|q| {
                reference_knn(&data.train, &data.labels, recipe.dataset.window, q, recipe.queries.k)
            })
            .collect()),
        OracleMode::Cross => {
            // Serial flat single-shard index as the reference; its own
            // conservation identity is still checked, so a reference
            // that silently skips candidates cannot anchor the run.
            let index = build_index(data, recipe, Grid::reference_point())?;
            let mut searcher = index.searcher();
            let opts = dtw_bounds::index::query::QueryOptions::k(recipe.queries.k);
            let mut out = Vec::with_capacity(data.queries.len());
            for (qi, q) in data.queries.iter().enumerate() {
                let outcome = searcher.query_values::<Squared>(q, &opts);
                oracle.check_knn_conservation(
                    &format!("truth/cross/q{qi}"),
                    &outcome.stats,
                    index.len(),
                )?;
                out.push(pairs(&outcome));
            }
            Ok(out)
        }
    }
}

/// Reference stream matches, per the recipe's oracle mode.
fn stream_truth(recipe: &Recipe, data: &BenchData, oracle: &mut Oracle) -> Result<Vec<StreamTriple>, RunError> {
    match recipe.oracle {
        OracleMode::Brute => Ok(reference_stream(
            &data.train,
            &data.labels,
            recipe.dataset.window,
            &data.stream,
            recipe.dataset.len,
            recipe.stream.hop,
            recipe.stream.threshold,
        )),
        OracleMode::Cross => {
            let index = build_index(data, recipe, Grid::reference_point())?;
            let opts = dtw_bounds::stream::SubsequenceOptions::threshold(recipe.stream.threshold)
                .with_hop(recipe.stream.hop)
                .with_znorm(false)
                .with_threads(1);
            let report = index.subsequence_scan::<Squared>(&data.stream, opts)?;
            check_stream_conservation(oracle, "truth/cross/stream", &report, index.len())?;
            Ok(stream_pairs(&report))
        }
    }
}

/// Run a recipe end to end and return the report.
pub fn run(recipe: &Recipe) -> Result<Report, RunError> {
    let data = materialize(recipe);
    let mut oracle = Oracle::default();
    let needs_knn = recipe.scenarios.iter().any(|s| {
        matches!(
            s,
            ScenarioKind::Knn
                | ScenarioKind::Batched
                | ScenarioKind::ColdStart
                | ScenarioKind::Snapshot
                | ScenarioKind::Live
        )
    });
    let needs_stream = recipe
        .scenarios
        .iter()
        .any(|s| matches!(s, ScenarioKind::Stream));
    let knn_truth = if needs_knn { knn_truth(recipe, &data, &mut oracle)? } else { Vec::new() };
    let stream_truth =
        if needs_stream { stream_truth(recipe, &data, &mut oracle)? } else { Vec::new() };

    let mut ctx = RunCtx {
        recipe,
        data: &data,
        knn_truth,
        stream_truth,
        oracle,
        metrics: Vec::new(),
    };
    for kind in &recipe.scenarios {
        match kind {
            ScenarioKind::ColdStart => scenario::cold_start::run(&mut ctx)?,
            ScenarioKind::Knn => scenario::knn::run(&mut ctx)?,
            ScenarioKind::Batched => scenario::batched::run(&mut ctx)?,
            ScenarioKind::Stream => scenario::stream::run(&mut ctx)?,
            ScenarioKind::Snapshot => scenario::snapshot::run(&mut ctx)?,
            ScenarioKind::Live => scenario::live::run(&mut ctx)?,
            ScenarioKind::Kernel => scenario::kernel::run(&mut ctx)?,
        }
    }

    Ok(Report {
        schema_version: SCHEMA_VERSION,
        recipe: recipe.name.clone(),
        seed: recipe.seed,
        oracle_mode: recipe.oracle.name().to_string(),
        oracle_checks: ctx.oracle.checks,
        isa: dtw_bounds::simd::isa_name().to_string(),
        scenarios: recipe.scenarios.iter().map(|s| s.name().to_string()).collect(),
        metrics: ctx.metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recipe::{
        DatasetSpec, Family, Grid, LiveSpec, QueryMix, QuerySpec, StreamSpec, WalMode,
    };

    /// A deliberately tiny recipe so the full runner (all seven
    /// scenarios, brute oracles) stays fast enough for `cargo test`.
    fn tiny(oracle: OracleMode) -> Recipe {
        Recipe {
            name: "tiny".into(),
            description: "runner unit".into(),
            seed: 3,
            dataset: DatasetSpec {
                family: Family::Sinusoid,
                series: 16,
                len: 24,
                window: 3,
                classes: 4,
            },
            queries: QuerySpec { count: 3, mix: QueryMix::Mixed, k: 2 },
            grid: Grid { threads: vec![1, 2], shards: vec![1, 2], clusters: vec![0, 3] },
            scenarios: ScenarioKind::ALL.to_vec(),
            stream: StreamSpec { samples: 160, hop: 2, threshold: 18.0 },
            // Both durability modes, so the runner unit exercises the
            // wal-always anchor end to end (real temp files + fsync).
            live: LiveSpec { inserts: 6, deletes: 3, wal: vec![WalMode::Off, WalMode::Always] },
            oracle,
        }
    }

    #[test]
    fn tiny_recipe_passes_every_oracle_in_brute_mode() {
        let report = run(&tiny(OracleMode::Brute)).unwrap();
        assert_eq!(report.scenarios.len(), 7);
        assert!(report.oracle_checks > 50, "oracle barely ran: {}", report.oracle_checks);
        assert_eq!(report.isa, dtw_bounds::simd::isa_name());
        assert!(report.metric("knn/t1.s1.c0/ns_per_query").is_some());
        assert!(report.metric("stream/t2.s2.c3/matches").is_some());
        assert!(report.metric("live/t2.s2.c3.wal-off/compact_ns").is_some());
        assert!(report.metric("live/t2.s2.c3.wal-always/insert_ns").is_some());
        let isa = dtw_bounds::simd::isa_name();
        assert!(
            report.metric(&format!("kernel/{isa}/keogh_sq/cells_per_sec")).is_some(),
            "kernel scenario must report the active ISA's throughput"
        );
    }

    #[test]
    fn cross_mode_agrees_with_itself() {
        let report = run(&tiny(OracleMode::Cross)).unwrap();
        assert!(report.oracle_checks > 50);
    }

    #[test]
    fn brute_and_cross_reports_carry_identical_deterministic_counts() {
        let a = run(&tiny(OracleMode::Brute)).unwrap();
        let b = run(&tiny(OracleMode::Cross)).unwrap();
        for id in ["stream/t1.s1.c0/windows", "stream/t2.s2.c3/matches"] {
            let (ma, mb) = (a.metric(id).unwrap(), b.metric(id).unwrap());
            assert_eq!(ma.value, mb.value, "{id}");
        }
    }
}
