//! Declarative bench recipes: what to generate, which scenarios to run,
//! and the thread × shard × cluster grid to run them over.
//!
//! A recipe is a TOML file (see `dtw-bench/recipes/`) parsed by the
//! minimal parser in [`crate::toml`]. Parsing is **strict**: unknown
//! tables or keys, missing keys, wrong value types and degenerate grids
//! are all rejected with a typed [`RecipeError`] carrying the source
//! line. [`Recipe::to_toml_string`] emits the canonical form, and
//! `parse(to_toml_string(r)) == r` round-trips every field (pinned by
//! `tests/recipe.rs`).

use std::fmt;

use crate::toml::{Doc, Entry, Table, Value};

/// Synthetic dataset family (generators live in `dtw_bounds::data::synthetic`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Smooth sums of sinusoids — the envelope-friendly easy case.
    Sinusoid,
    /// Gaussian random walks — unstructured, window-limited pruning.
    RandomWalk,
    /// Worst-case-warping oscillators — envelopes go slack, the stress
    /// case for prune-rate claims.
    Adversarial,
}

impl Family {
    /// Canonical (re-parseable) name.
    pub fn name(self) -> &'static str {
        match self {
            Family::Sinusoid => "sinusoid",
            Family::RandomWalk => "random-walk",
            Family::Adversarial => "adversarial",
        }
    }

    fn parse(s: &str) -> Option<Family> {
        match s {
            "sinusoid" => Some(Family::Sinusoid),
            "random-walk" => Some(Family::RandomWalk),
            "adversarial" => Some(Family::Adversarial),
            _ => None,
        }
    }
}

/// How queries relate to the indexed corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryMix {
    /// Perturbed copies of indexed series — the prunable regime.
    Near,
    /// Fresh draws from the family — no planted neighbor.
    Fresh,
    /// Alternating near/fresh.
    Mixed,
}

impl QueryMix {
    /// Canonical (re-parseable) name.
    pub fn name(self) -> &'static str {
        match self {
            QueryMix::Near => "near",
            QueryMix::Fresh => "fresh",
            QueryMix::Mixed => "mixed",
        }
    }

    fn parse(s: &str) -> Option<QueryMix> {
        match s {
            "near" => Some(QueryMix::Near),
            "fresh" => Some(QueryMix::Fresh),
            "mixed" => Some(QueryMix::Mixed),
            _ => None,
        }
    }
}

/// How the exactness oracle derives its reference answers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OracleMode {
    /// Independent full-matrix DTW brute force (no index code on the
    /// reference path). Affordable for quick recipes; quadratic in the
    /// corpus for streams.
    Brute,
    /// Serial flat single-shard index as the reference; every other grid
    /// point must agree with it bit-for-bit. For full-scale recipes
    /// where the stream brute force is intractable.
    Cross,
}

impl OracleMode {
    /// Canonical (re-parseable) name.
    pub fn name(self) -> &'static str {
        match self {
            OracleMode::Brute => "brute",
            OracleMode::Cross => "cross",
        }
    }

    fn parse(s: &str) -> Option<OracleMode> {
        match s {
            "brute" => Some(OracleMode::Brute),
            "cross" => Some(OracleMode::Cross),
            _ => None,
        }
    }
}

/// One benchmark scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Cold start: rebuild-from-raw vs. snapshot load, first query served.
    ColdStart,
    /// Steady-state scalar k-NN over the grid.
    Knn,
    /// Batched screening (the `SortedPrecomputed` prefilter path).
    Batched,
    /// Stream firehose: subsequence threshold scan over the grid.
    Stream,
    /// Snapshot save/load round-trip integrity.
    Snapshot,
    /// Mixed query+stream over live mutation (insert/delete/compact
    /// under load), pinned to a cold rebuild.
    Live,
    /// SIMD kernel microbenchmarks: cells/sec per available ISA per
    /// bound kernel, with a bit-equality oracle against the scalar
    /// lane-protocol reference.
    Kernel,
}

impl ScenarioKind {
    /// Every scenario, in canonical execution order.
    pub const ALL: [ScenarioKind; 7] = [
        ScenarioKind::ColdStart,
        ScenarioKind::Knn,
        ScenarioKind::Batched,
        ScenarioKind::Stream,
        ScenarioKind::Snapshot,
        ScenarioKind::Live,
        ScenarioKind::Kernel,
    ];

    /// Canonical (re-parseable) name.
    pub fn name(self) -> &'static str {
        match self {
            ScenarioKind::ColdStart => "cold-start",
            ScenarioKind::Knn => "knn",
            ScenarioKind::Batched => "batched",
            ScenarioKind::Stream => "stream",
            ScenarioKind::Snapshot => "snapshot",
            ScenarioKind::Live => "live",
            ScenarioKind::Kernel => "kernel",
        }
    }

    fn parse(s: &str) -> Option<ScenarioKind> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }
}

impl fmt::Display for ScenarioKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// `[dataset]`: what to generate and index.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    /// Generator family.
    pub family: Family,
    /// Indexed series count.
    pub series: usize,
    /// Series length ℓ.
    pub len: usize,
    /// Warping window `w` (Sakoe–Chiba radius).
    pub window: usize,
    /// Label classes (round-robin over series).
    pub classes: usize,
}

/// `[queries]`: the query workload.
#[derive(Debug, Clone, PartialEq)]
pub struct QuerySpec {
    /// Number of queries.
    pub count: usize,
    /// How queries relate to the corpus.
    pub mix: QueryMix,
    /// Neighbors per query.
    pub k: usize,
}

/// One grid point: a (threads, shards, clusters) configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GridPoint {
    /// Search worker threads.
    pub threads: usize,
    /// Contiguous candidate shards.
    pub shards: usize,
    /// Pivot clusters per shard (0 = off).
    pub clusters: usize,
}

impl GridPoint {
    /// Metric-id tag, e.g. `t2.s4.c8`.
    pub fn tag(&self) -> String {
        format!("t{}.s{}.c{}", self.threads, self.shards, self.clusters)
    }
}

/// `[grid]`: the thread × shard × cluster sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct Grid {
    /// Thread counts to sweep.
    pub threads: Vec<usize>,
    /// Shard counts to sweep.
    pub shards: Vec<usize>,
    /// Cluster counts to sweep (0 = clustering off).
    pub clusters: Vec<usize>,
}

impl Grid {
    /// The full cartesian product, threads-major.
    pub fn points(&self) -> Vec<GridPoint> {
        let mut out = Vec::new();
        for &threads in &self.threads {
            for &shards in &self.shards {
                for &clusters in &self.clusters {
                    out.push(GridPoint { threads, shards, clusters });
                }
            }
        }
        out
    }

    /// The serial flat reference point every sweep is compared against.
    pub fn reference_point() -> GridPoint {
        GridPoint { threads: 1, shards: 1, clusters: 0 }
    }

    /// The most aggressive configuration — used by the scenarios that
    /// run at one representative point instead of the full sweep.
    pub fn representative_point(&self) -> GridPoint {
        GridPoint {
            threads: self.threads.iter().copied().max().unwrap_or(1),
            shards: self.shards.iter().copied().max().unwrap_or(1),
            clusters: self.clusters.iter().copied().max().unwrap_or(0),
        }
    }
}

/// `[stream]`: the firehose workload.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    /// Stream length in samples.
    pub samples: usize,
    /// Stride between evaluated window starts.
    pub hop: usize,
    /// Match threshold τ (squared-delta DTW distance).
    pub threshold: f64,
}

/// Write-ahead-log durability modes the live scenario sweeps.
///
/// `off` measures the bare in-memory mutation path; `always` anchors
/// the engine to a real on-disk snapshot and pays an append + fsync
/// before every ack, so the off/always delta on `insert_ns` *is* the
/// durability tax.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalMode {
    /// No log: acks return as soon as the delta shard applies.
    Off,
    /// `FsyncPolicy::Always`: append + fsync before every ack.
    Always,
}

impl WalMode {
    /// Canonical (re-parseable) name.
    pub fn name(self) -> &'static str {
        match self {
            WalMode::Off => "off",
            WalMode::Always => "always",
        }
    }

    fn parse(s: &str) -> Option<WalMode> {
        match s {
            "off" => Some(WalMode::Off),
            "always" => Some(WalMode::Always),
            _ => None,
        }
    }
}

/// `[live]`: the mutation workload.
#[derive(Debug, Clone, PartialEq)]
pub struct LiveSpec {
    /// Insertions to apply.
    pub inserts: usize,
    /// Deletions to apply.
    pub deletes: usize,
    /// Durability modes to sweep (optional; defaults to `["off"]`).
    pub wal: Vec<WalMode>,
}

/// A fully-parsed, validated recipe.
#[derive(Debug, Clone, PartialEq)]
pub struct Recipe {
    /// Recipe name (used in the report and in metric provenance).
    pub name: String,
    /// One-line description.
    pub description: String,
    /// Master seed: the whole run is a pure function of the recipe.
    pub seed: u64,
    /// What to generate and index.
    pub dataset: DatasetSpec,
    /// The query workload.
    pub queries: QuerySpec,
    /// The thread × shard × cluster sweep.
    pub grid: Grid,
    /// Scenarios to run, in order.
    pub scenarios: Vec<ScenarioKind>,
    /// The firehose workload.
    pub stream: StreamSpec,
    /// The mutation workload.
    pub live: LiveSpec,
    /// How reference answers are derived.
    pub oracle: OracleMode,
}

/// Typed recipe errors — each names the table/key and source line.
#[derive(Debug, Clone, PartialEq)]
pub enum RecipeError {
    /// The TOML layer rejected the file.
    Toml {
        /// 1-based source line.
        line: usize,
        /// The parser's message.
        message: String,
    },
    /// A table this schema does not define.
    UnknownTable {
        /// The offending table name.
        table: String,
        /// 1-based source line of its header.
        line: usize,
    },
    /// A key this schema does not define (also raised for keys outside
    /// any table).
    UnknownKey {
        /// The table the key appeared in (empty = root).
        table: String,
        /// The offending key.
        key: String,
        /// 1-based source line.
        line: usize,
    },
    /// A required key (or its whole table) is absent.
    MissingKey {
        /// The table the key belongs to.
        table: String,
        /// The missing key.
        key: String,
    },
    /// A key is present but its value is unusable.
    InvalidValue {
        /// The table the key appeared in.
        table: String,
        /// The key.
        key: String,
        /// 1-based source line.
        line: usize,
        /// Why the value was rejected.
        message: String,
    },
    /// The grid is degenerate (empty axis, zero counts, or counts the
    /// dataset cannot satisfy).
    InvalidGrid {
        /// Why the grid was rejected.
        message: String,
    },
}

impl fmt::Display for RecipeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecipeError::Toml { line, message } => write!(f, "toml: line {line}: {message}"),
            RecipeError::UnknownTable { table, line } => {
                write!(f, "line {line}: unknown table [{table}]")
            }
            RecipeError::UnknownKey { table, key, line } => {
                if table.is_empty() {
                    write!(f, "line {line}: key `{key}` outside any table")
                } else {
                    write!(f, "line {line}: unknown key `{key}` in [{table}]")
                }
            }
            RecipeError::MissingKey { table, key } => {
                write!(f, "missing key `{key}` in [{table}]")
            }
            RecipeError::InvalidValue { table, key, line, message } => {
                write!(f, "line {line}: [{table}] {key}: {message}")
            }
            RecipeError::InvalidGrid { message } => write!(f, "invalid grid: {message}"),
        }
    }
}

impl std::error::Error for RecipeError {}

// ---- value extraction helpers -----------------------------------------

fn bad(t: &str, e: &Entry, message: impl Into<String>) -> RecipeError {
    RecipeError::InvalidValue {
        table: t.to_string(),
        key: e.key.clone(),
        line: e.line,
        message: message.into(),
    }
}

fn as_usize(t: &str, e: &Entry) -> Result<usize, RecipeError> {
    match e.value {
        Value::Int(i) if i >= 0 => Ok(i as usize),
        Value::Int(i) => Err(bad(t, e, format!("expected a non-negative integer, got {i}"))),
        ref v => Err(bad(t, e, format!("expected an integer, got {}", v.type_name()))),
    }
}

fn as_u64(t: &str, e: &Entry) -> Result<u64, RecipeError> {
    match e.value {
        Value::Int(i) if i >= 0 => Ok(i as u64),
        Value::Int(i) => Err(bad(t, e, format!("expected a non-negative integer, got {i}"))),
        ref v => Err(bad(t, e, format!("expected an integer, got {}", v.type_name()))),
    }
}

fn as_f64(t: &str, e: &Entry) -> Result<f64, RecipeError> {
    match e.value {
        Value::Float(f) => Ok(f),
        Value::Int(i) => Ok(i as f64),
        ref v => Err(bad(t, e, format!("expected a number, got {}", v.type_name()))),
    }
}

fn as_str<'a>(t: &str, e: &'a Entry) -> Result<&'a str, RecipeError> {
    match e.value {
        Value::Str(ref s) => Ok(s.as_str()),
        ref v => Err(bad(t, e, format!("expected a string, got {}", v.type_name()))),
    }
}

fn as_usize_list(t: &str, e: &Entry) -> Result<Vec<usize>, RecipeError> {
    let items = match e.value {
        Value::Array(ref items) => items,
        ref v => {
            return Err(bad(t, e, format!("expected an array of integers, got {}", v.type_name())))
        }
    };
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        match *item {
            Value::Int(i) if i >= 0 => out.push(i as usize),
            ref v => {
                return Err(bad(
                    t,
                    e,
                    format!("expected non-negative integers, got {}", v.type_name()),
                ))
            }
        }
    }
    Ok(out)
}

fn as_str_list(t: &str, e: &Entry) -> Result<Vec<String>, RecipeError> {
    let items = match e.value {
        Value::Array(ref items) => items,
        ref v => {
            return Err(bad(t, e, format!("expected an array of strings, got {}", v.type_name())))
        }
    };
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        match *item {
            Value::Str(ref s) => out.push(s.clone()),
            ref v => return Err(bad(t, e, format!("expected strings, got {}", v.type_name()))),
        }
    }
    Ok(out)
}

fn missing(table: &str, key: &str) -> RecipeError {
    RecipeError::MissingKey { table: table.to_string(), key: key.to_string() }
}

fn require<T>(opt: Option<T>, table: &str, key: &str) -> Result<T, RecipeError> {
    opt.ok_or_else(|| missing(table, key))
}

/// The tables this schema defines, in canonical emit order.
const TABLES: [&str; 8] =
    ["recipe", "dataset", "queries", "grid", "scenarios", "stream", "live", "oracle"];

impl Recipe {
    /// Parse and validate a recipe from TOML text.
    pub fn parse(text: &str) -> Result<Recipe, RecipeError> {
        let doc = Doc::parse(text)
            .map_err(|e| RecipeError::Toml { line: e.line, message: e.message })?;

        // Reject unknown/root tables up front so typos fail loudly.
        for table in &doc.tables {
            if table.name.is_empty() {
                let e = &table.entries[0];
                return Err(RecipeError::UnknownKey {
                    table: String::new(),
                    key: e.key.clone(),
                    line: e.line,
                });
            }
            if !TABLES.contains(&table.name.as_str()) {
                return Err(RecipeError::UnknownTable {
                    table: table.name.clone(),
                    line: table.line,
                });
            }
        }
        let get = |name: &str| -> Result<&Table, RecipeError> {
            doc.table(name).ok_or_else(|| missing(name, "*"))
        };

        // [recipe]
        let t = get("recipe")?;
        let (mut name, mut description, mut seed) = (None, None, None);
        for e in &t.entries {
            match e.key.as_str() {
                "name" => name = Some(as_str("recipe", e)?.to_string()),
                "description" => description = Some(as_str("recipe", e)?.to_string()),
                "seed" => seed = Some(as_u64("recipe", e)?),
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "recipe".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let name = require(name, "recipe", "name")?;
        let description = description.unwrap_or_default();
        let seed = require(seed, "recipe", "seed")?;

        // [dataset]
        let t = get("dataset")?;
        let (mut family, mut series, mut len, mut window, mut classes) =
            (None, None, None, None, None);
        for e in &t.entries {
            match e.key.as_str() {
                "family" => {
                    let s = as_str("dataset", e)?;
                    family = Some(Family::parse(s).ok_or_else(|| {
                        bad("dataset", e, format!("unknown family `{s}` (sinusoid | random-walk | adversarial)"))
                    })?);
                }
                "series" => series = Some(as_usize("dataset", e)?),
                "len" => len = Some(as_usize("dataset", e)?),
                "window" => window = Some(as_usize("dataset", e)?),
                "classes" => classes = Some(as_usize("dataset", e)?),
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "dataset".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let dataset = DatasetSpec {
            family: require(family, "dataset", "family")?,
            series: require(series, "dataset", "series")?,
            len: require(len, "dataset", "len")?,
            window: require(window, "dataset", "window")?,
            classes: require(classes, "dataset", "classes")?,
        };

        // [queries]
        let t = get("queries")?;
        let (mut count, mut mix, mut k) = (None, None, None);
        for e in &t.entries {
            match e.key.as_str() {
                "count" => count = Some(as_usize("queries", e)?),
                "mix" => {
                    let s = as_str("queries", e)?;
                    mix = Some(QueryMix::parse(s).ok_or_else(|| {
                        bad("queries", e, format!("unknown mix `{s}` (near | fresh | mixed)"))
                    })?);
                }
                "k" => k = Some(as_usize("queries", e)?),
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "queries".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let queries = QuerySpec {
            count: require(count, "queries", "count")?,
            mix: require(mix, "queries", "mix")?,
            k: require(k, "queries", "k")?,
        };

        // [grid]
        let t = get("grid")?;
        let (mut threads, mut shards, mut clusters) = (None, None, None);
        for e in &t.entries {
            match e.key.as_str() {
                "threads" => threads = Some(as_usize_list("grid", e)?),
                "shards" => shards = Some(as_usize_list("grid", e)?),
                "clusters" => clusters = Some(as_usize_list("grid", e)?),
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "grid".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let grid = Grid {
            threads: require(threads, "grid", "threads")?,
            shards: require(shards, "grid", "shards")?,
            clusters: require(clusters, "grid", "clusters")?,
        };

        // [scenarios]
        let t = get("scenarios")?;
        let mut run = None;
        for e in &t.entries {
            match e.key.as_str() {
                "run" => {
                    let names = as_str_list("scenarios", e)?;
                    let mut kinds = Vec::with_capacity(names.len());
                    for n in &names {
                        let kind = ScenarioKind::parse(n).ok_or_else(|| {
                            bad("scenarios", e, format!("unknown scenario `{n}`"))
                        })?;
                        if kinds.contains(&kind) {
                            return Err(bad(
                                "scenarios",
                                e,
                                format!("scenario `{n}` listed twice"),
                            ));
                        }
                        kinds.push(kind);
                    }
                    run = Some(kinds);
                }
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "scenarios".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let scenarios = require(run, "scenarios", "run")?;

        // [stream]
        let t = get("stream")?;
        let (mut samples, mut hop, mut threshold) = (None, None, None);
        for e in &t.entries {
            match e.key.as_str() {
                "samples" => samples = Some(as_usize("stream", e)?),
                "hop" => hop = Some(as_usize("stream", e)?),
                "threshold" => threshold = Some(as_f64("stream", e)?),
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "stream".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let stream = StreamSpec {
            samples: require(samples, "stream", "samples")?,
            hop: require(hop, "stream", "hop")?,
            threshold: require(threshold, "stream", "threshold")?,
        };

        // [live]
        let t = get("live")?;
        let (mut inserts, mut deletes, mut wal) = (None, None, None);
        for e in &t.entries {
            match e.key.as_str() {
                "inserts" => inserts = Some(as_usize("live", e)?),
                "deletes" => deletes = Some(as_usize("live", e)?),
                "wal" => {
                    let names = as_str_list("live", e)?;
                    let mut modes = Vec::with_capacity(names.len());
                    for n in &names {
                        let mode = WalMode::parse(n).ok_or_else(|| {
                            bad("live", e, format!("unknown wal mode `{n}` (off | always)"))
                        })?;
                        if modes.contains(&mode) {
                            return Err(bad("live", e, format!("wal mode `{n}` listed twice")));
                        }
                        modes.push(mode);
                    }
                    wal = Some(modes);
                }
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "live".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let live = LiveSpec {
            inserts: require(inserts, "live", "inserts")?,
            deletes: require(deletes, "live", "deletes")?,
            wal: wal.unwrap_or_else(|| vec![WalMode::Off]),
        };

        // [oracle]
        let t = get("oracle")?;
        let mut mode = None;
        for e in &t.entries {
            match e.key.as_str() {
                "mode" => {
                    let s = as_str("oracle", e)?;
                    mode = Some(OracleMode::parse(s).ok_or_else(|| {
                        bad("oracle", e, format!("unknown oracle mode `{s}` (brute | cross)"))
                    })?);
                }
                _ => {
                    return Err(RecipeError::UnknownKey {
                        table: "oracle".into(),
                        key: e.key.clone(),
                        line: e.line,
                    })
                }
            }
        }
        let oracle = require(mode, "oracle", "mode")?;

        let recipe = Recipe {
            name,
            description,
            seed,
            dataset,
            queries,
            grid,
            scenarios,
            stream,
            live,
            oracle,
        };
        recipe.validate()?;
        Ok(recipe)
    }

    /// Cross-field validation (called by [`Recipe::parse`]).
    pub fn validate(&self) -> Result<(), RecipeError> {
        let grid_err = |message: String| Err(RecipeError::InvalidGrid { message });
        let d = &self.dataset;
        if d.series < 2 {
            return grid_err(format!("dataset.series = {} (need at least 2)", d.series));
        }
        if d.len < 8 {
            return grid_err(format!("dataset.len = {} (need at least 8)", d.len));
        }
        if d.window == 0 || d.window >= d.len {
            return grid_err(format!(
                "dataset.window = {} must be in 1..len ({})",
                d.window, d.len
            ));
        }
        if d.classes == 0 || d.classes > d.series {
            return grid_err(format!(
                "dataset.classes = {} must be in 1..=series ({})",
                d.classes, d.series
            ));
        }
        if self.queries.count == 0 {
            return grid_err("queries.count = 0".into());
        }
        if self.queries.k == 0 || self.queries.k > d.series {
            return grid_err(format!(
                "queries.k = {} must be in 1..=series ({})",
                self.queries.k, d.series
            ));
        }
        for (axis, values) in [
            ("threads", &self.grid.threads),
            ("shards", &self.grid.shards),
            ("clusters", &self.grid.clusters),
        ] {
            if values.is_empty() {
                return grid_err(format!("grid.{axis} is empty"));
            }
        }
        if self.grid.threads.contains(&0) {
            return grid_err("grid.threads contains 0 (thread counts must be explicit)".into());
        }
        if self.grid.shards.contains(&0) {
            return grid_err("grid.shards contains 0".into());
        }
        if let Some(&s) = self.grid.shards.iter().find(|&&s| s > d.series) {
            return grid_err(format!("grid.shards contains {s} > dataset.series ({})", d.series));
        }
        if let Some(&c) = self.grid.clusters.iter().find(|&&c| c > d.series) {
            return grid_err(format!(
                "grid.clusters contains {c} > dataset.series ({})",
                d.series
            ));
        }
        if self.scenarios.is_empty() {
            return grid_err("scenarios.run is empty".into());
        }
        if self.stream.samples < d.len {
            return grid_err(format!(
                "stream.samples = {} shorter than one window ({})",
                self.stream.samples, d.len
            ));
        }
        if self.stream.hop == 0 {
            return grid_err("stream.hop = 0".into());
        }
        if !(self.stream.threshold > 0.0) {
            return grid_err(format!("stream.threshold = {} must be > 0", self.stream.threshold));
        }
        if self.live.deletes >= d.series {
            return grid_err(format!(
                "live.deletes = {} must stay below dataset.series ({})",
                self.live.deletes, d.series
            ));
        }
        if self.live.wal.is_empty() {
            return grid_err("live.wal is empty (omit the key for the `off` default)".into());
        }
        Ok(())
    }

    /// Emit the canonical TOML form; `parse` round-trips it exactly.
    pub fn to_toml_string(&self) -> String {
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let list = |xs: &[usize]| {
            let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
            format!("[{}]", items.join(", "))
        };
        let scenarios: Vec<String> =
            self.scenarios.iter().map(|s| format!("\"{}\"", s.name())).collect();
        let mut out = String::new();
        out.push_str("[recipe]\n");
        out.push_str(&format!("name = \"{}\"\n", esc(&self.name)));
        out.push_str(&format!("description = \"{}\"\n", esc(&self.description)));
        out.push_str(&format!("seed = {}\n", self.seed));
        out.push_str("\n[dataset]\n");
        out.push_str(&format!("family = \"{}\"\n", self.dataset.family.name()));
        out.push_str(&format!("series = {}\n", self.dataset.series));
        out.push_str(&format!("len = {}\n", self.dataset.len));
        out.push_str(&format!("window = {}\n", self.dataset.window));
        out.push_str(&format!("classes = {}\n", self.dataset.classes));
        out.push_str("\n[queries]\n");
        out.push_str(&format!("count = {}\n", self.queries.count));
        out.push_str(&format!("mix = \"{}\"\n", self.queries.mix.name()));
        out.push_str(&format!("k = {}\n", self.queries.k));
        out.push_str("\n[grid]\n");
        out.push_str(&format!("threads = {}\n", list(&self.grid.threads)));
        out.push_str(&format!("shards = {}\n", list(&self.grid.shards)));
        out.push_str(&format!("clusters = {}\n", list(&self.grid.clusters)));
        out.push_str("\n[scenarios]\n");
        out.push_str(&format!("run = [{}]\n", scenarios.join(", ")));
        out.push_str("\n[stream]\n");
        out.push_str(&format!("samples = {}\n", self.stream.samples));
        out.push_str(&format!("hop = {}\n", self.stream.hop));
        out.push_str(&format!("threshold = {}\n", fmt_float(self.stream.threshold)));
        let wal: Vec<String> =
            self.live.wal.iter().map(|m| format!("\"{}\"", m.name())).collect();
        out.push_str("\n[live]\n");
        out.push_str(&format!("inserts = {}\n", self.live.inserts));
        out.push_str(&format!("deletes = {}\n", self.live.deletes));
        out.push_str(&format!("wal = [{}]\n", wal.join(", ")));
        out.push_str("\n[oracle]\n");
        out.push_str(&format!("mode = \"{}\"\n", self.oracle.name()));
        out
    }
}

/// Float literal that TOML re-parses as a float (always keeps a `.`).
fn fmt_float(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{v:.1}")
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample() -> Recipe {
        Recipe {
            name: "unit".into(),
            description: "unit-test recipe".into(),
            seed: 7,
            dataset: DatasetSpec {
                family: Family::RandomWalk,
                series: 24,
                len: 32,
                window: 3,
                classes: 4,
            },
            queries: QuerySpec { count: 3, mix: QueryMix::Mixed, k: 2 },
            grid: Grid { threads: vec![1, 2], shards: vec![1, 2], clusters: vec![0, 4] },
            scenarios: vec![ScenarioKind::Knn, ScenarioKind::Stream],
            stream: StreamSpec { samples: 400, hop: 2, threshold: 12.5 },
            live: LiveSpec {
                inserts: 6,
                deletes: 2,
                wal: vec![WalMode::Off, WalMode::Always],
            },
            oracle: OracleMode::Brute,
        }
    }

    #[test]
    fn omitted_wal_axis_defaults_to_off() {
        let text = sample().to_toml_string().replace("wal = [\"off\", \"always\"]\n", "");
        assert_ne!(text, sample().to_toml_string());
        assert_eq!(Recipe::parse(&text).unwrap().live.wal, vec![WalMode::Off]);
    }

    #[test]
    fn wal_axis_rejects_unknown_duplicate_and_empty_modes() {
        let swap = |to: &str| sample().to_toml_string().replace("wal = [\"off\", \"always\"]", to);
        match Recipe::parse(&swap("wal = [\"sometimes\"]")).unwrap_err() {
            RecipeError::InvalidValue { table, key, message, .. } => {
                assert_eq!((table.as_str(), key.as_str()), ("live", "wal"));
                assert!(message.contains("sometimes"), "{message}");
            }
            other => panic!("want InvalidValue, got {other:?}"),
        }
        assert!(matches!(
            Recipe::parse(&swap("wal = [\"off\", \"off\"]")),
            Err(RecipeError::InvalidValue { .. })
        ));
        assert!(matches!(
            Recipe::parse(&swap("wal = []")),
            Err(RecipeError::InvalidGrid { .. })
        ));
    }

    #[test]
    fn canonical_form_round_trips() {
        let r = sample();
        let parsed = Recipe::parse(&r.to_toml_string()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn unknown_key_and_table_are_typed() {
        let mut text = sample().to_toml_string();
        text.push_str("\n[extra]\nx = 1\n");
        match Recipe::parse(&text).unwrap_err() {
            RecipeError::UnknownTable { table, .. } => assert_eq!(table, "extra"),
            other => panic!("want UnknownTable, got {other:?}"),
        }
        let text = sample().to_toml_string().replace("seed = 7", "sede = 7");
        match Recipe::parse(&text).unwrap_err() {
            RecipeError::UnknownKey { table, key, .. } => {
                assert_eq!((table.as_str(), key.as_str()), ("recipe", "sede"));
            }
            other => panic!("want UnknownKey, got {other:?}"),
        }
    }

    #[test]
    fn degenerate_grids_are_rejected() {
        let mut r = sample();
        r.grid.threads = vec![];
        assert!(matches!(r.validate(), Err(RecipeError::InvalidGrid { .. })));
        let mut r = sample();
        r.grid.shards = vec![0];
        assert!(matches!(r.validate(), Err(RecipeError::InvalidGrid { .. })));
        let mut r = sample();
        r.grid.clusters = vec![r.dataset.series + 1];
        assert!(matches!(r.validate(), Err(RecipeError::InvalidGrid { .. })));
    }

    #[test]
    fn wrong_types_are_invalid_values() {
        let text = sample().to_toml_string().replace("count = 3", "count = \"three\"");
        match Recipe::parse(&text).unwrap_err() {
            RecipeError::InvalidValue { table, key, .. } => {
                assert_eq!((table.as_str(), key.as_str()), ("queries", "count"));
            }
            other => panic!("want InvalidValue, got {other:?}"),
        }
    }
}
