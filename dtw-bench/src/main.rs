//! The `dtw-bench` binary: run recipes, gate regressions, list recipes.
//!
//! Exit codes are part of the CI contract:
//!
//! | code | meaning |
//! |------|---------|
//! | 0    | everything passed |
//! | 1    | usage / config / I/O error |
//! | 2    | **oracle failure** — wrong answers; never warn-only |
//! | 3    | perf regression past tolerance (0 instead when `DTWB_REGRESSION_WARN_ONLY` is set) |

use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

use dtw_bounds::cli::Args;

use dtw_bench::gate;
use dtw_bench::recipe::Recipe;
use dtw_bench::report::{
    default_baseline_path, default_report_path, recipes_dir, Report,
};
use dtw_bench::runner::{self, RunError};

fn usage() -> &'static str {
    "dtw-bench — recipe-driven benchmarks with exactness oracles\n\
     \n\
     USAGE:\n\
       dtw-bench run [--recipe NAME|PATH] [--out PATH] [--baseline PATH]\n\
       dtw-bench check [--report PATH] [--baseline PATH]\n\
       dtw-bench recipes\n\
     \n\
     `run` executes the recipe's scenarios under the exactness oracles,\n\
     writes the schema-versioned report (default: bench-report.json at\n\
     the workspace root), then gates it against the baseline.\n\
     `check` re-gates an existing report without re-running anything.\n\
     Set DTWB_REGRESSION_WARN_ONLY=1 to report perf regressions without\n\
     failing; oracle failures always fail."
}

/// `--recipe` accepts a bare name (resolved in `dtw-bench/recipes/`)
/// or an explicit path (anything containing `/` or ending in `.toml`).
fn recipe_path(arg: &str) -> PathBuf {
    if arg.contains('/') || arg.ends_with(".toml") {
        PathBuf::from(arg)
    } else {
        recipes_dir().join(format!("{arg}.toml"))
    }
}

fn load_recipe(arg: &str) -> Result<Recipe, String> {
    let path = recipe_path(arg);
    let text = fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
    Recipe::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn warn_only() -> bool {
    std::env::var("DTWB_REGRESSION_WARN_ONLY").map_or(false, |v| !v.is_empty() && v != "0")
}

/// Gate `report` against the baseline at `path` (a missing baseline
/// file gates trivially). Returns the exit code.
fn run_gate(report: &Report, path: &PathBuf) -> ExitCode {
    let baseline = if path.exists() {
        match Report::load(path) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("dtw-bench: baseline {}: {e}", path.display());
                return ExitCode::from(1);
            }
        }
    } else {
        println!("gate: no baseline at {} — passing trivially", path.display());
        return ExitCode::SUCCESS;
    };
    let outcome = gate::check(report, &baseline);
    for note in &outcome.notes {
        println!("gate note: {note}");
    }
    println!("gate: {} metric(s) checked against {}", outcome.checked, path.display());
    if outcome.passed() {
        println!("gate: PASS");
        return ExitCode::SUCCESS;
    }
    for r in &outcome.regressions {
        eprintln!("gate REGRESSION: {r}");
    }
    if warn_only() {
        eprintln!(
            "gate: {} regression(s) — WARN ONLY (DTWB_REGRESSION_WARN_ONLY set)",
            outcome.regressions.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("gate: FAIL ({} regression(s))", outcome.regressions.len());
        ExitCode::from(3)
    }
}

fn cmd_run(args: &Args) -> ExitCode {
    let recipe = match load_recipe(&args.str_or("recipe", "quick")) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("dtw-bench: {e}");
            return ExitCode::from(1);
        }
    };
    println!(
        "recipe `{}`: {} series of len {} ({}), {} scenario(s), {} grid point(s), oracle {}",
        recipe.name,
        recipe.dataset.series,
        recipe.dataset.len,
        recipe.dataset.family.name(),
        recipe.scenarios.len(),
        recipe.grid.points().len(),
        recipe.oracle.name(),
    );
    let report = match runner::run(&recipe) {
        Ok(r) => r,
        Err(RunError::Oracle(e)) => {
            eprintln!("dtw-bench: ORACLE FAILURE: {e}");
            return ExitCode::from(2);
        }
        Err(RunError::Other(e)) => {
            eprintln!("dtw-bench: {e}");
            return ExitCode::from(1);
        }
    };
    println!(
        "ok: {} oracle check(s) passed, {} metric(s) collected",
        report.oracle_checks,
        report.metrics.len()
    );
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(default_report_path);
    if let Err(e) = report.save(&out) {
        eprintln!("dtw-bench: write {}: {e}", out.display());
        return ExitCode::from(1);
    }
    println!("report: {}", out.display());
    let baseline = args.get("baseline").map(PathBuf::from).unwrap_or_else(default_baseline_path);
    run_gate(&report, &baseline)
}

fn cmd_check(args: &Args) -> ExitCode {
    let path = args.get("report").map(PathBuf::from).unwrap_or_else(default_report_path);
    let report = match Report::load(&path) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("dtw-bench: report {}: {e}", path.display());
            return ExitCode::from(1);
        }
    };
    let baseline = args.get("baseline").map(PathBuf::from).unwrap_or_else(default_baseline_path);
    run_gate(&report, &baseline)
}

fn cmd_recipes() -> ExitCode {
    let dir = recipes_dir();
    let entries = match fs::read_dir(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("dtw-bench: read {}: {e}", dir.display());
            return ExitCode::from(1);
        }
    };
    let mut names: Vec<String> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |x| x == "toml"))
        .filter_map(|p| p.file_stem().map(|s| s.to_string_lossy().into_owned()))
        .collect();
    names.sort();
    for name in names {
        match load_recipe(&name) {
            Ok(r) => println!("{name}: {}", r.description),
            Err(e) => println!("{name}: INVALID ({e})"),
        }
    }
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("check") => cmd_check(&args),
        Some("recipes") => cmd_recipes(),
        Some("help") | None => {
            println!("{}", usage());
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("dtw-bench: unknown command `{other}`\n\n{}", usage());
            ExitCode::from(1)
        }
    }
}
